//! PJRT runtime: load AOT artifacts, compile once, execute from Rust —
//! with a **zero-copy host-tensor boundary**.
//!
//! The request path is Rust-only: `make artifacts` ran Python once to
//! lower every L1/L2 stage to HLO *text* (xla_extension 0.5.1 rejects
//! jax≥0.5's 64-bit-id serialized protos; the text parser reassigns
//! ids).  Here each stage is parsed, compiled on the PJRT CPU client,
//! cached, and invoked with `Literal` marshaling.
//!
//! ## The zero-copy boundary contract
//!
//! [`Runtime::run`] takes `&[ValueRef]` — borrowed typed slices — and
//! hands each slice to `buffer_from_host_buffer` verbatim.  Nothing
//! here copies, moves, or re-stages argument data:
//!
//! - a [`TensorBuf::View`] argument resolves into **pinned lease
//!   memory** (a swapper fetch, an activation checkpoint, the gradient
//!   flat buffer), so the fp16→f32 decode destination *is* the upload
//!   source — zero fp32 host-to-host copies between NVMe fetch and
//!   PJRT upload;
//! - an owned `Vec` argument uploads from its heap storage just the
//!   same; the two paths are bit-identical because the client consumes
//!   the identical slice either way ([`check_args`] is the shared
//!   validation, `bench_runtime` and the value-layer proptests prove
//!   the identity).
//!
//! **Mutation rules:** arguments are borrowed for the duration of
//! `run` only — PJRT reads each slice during its upload call and never
//! retains the borrow.  A lease backing a view is frozen read-only by
//! construction (`Lease::into_shared`): writers need `&mut Lease`,
//! which `Arc` denies while any view exists, so no component can
//! mutate staging out from under an in-flight upload.  Results come
//! back as owned [`Value`]s (the literal download allocates); callers
//! that want a result landed in lease memory pass destinations to
//! [`Runtime::run_into`].
//!
//! Per-call overhead: the stage spec is *borrowed* from the manifest
//! (no per-call clone), and the executable-cache lock is taken before
//! the upload loop, never inside it.

pub mod manifest;
mod value;

pub use manifest::{ArgSpec, Manifest, StageSpec};
pub use value::{F32Staging, F32View, TensorBuf, Value, ValueRef};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Validate `args` against a stage spec: arity, per-argument element
/// count, and dtype — exactly the checks [`Runtime::run`] applies
/// before any upload.  Public because it *is* the boundary's
/// data-plane contract: the PJRT client consumes each [`ValueRef`]'s
/// slice verbatim after this passes, so two argument lists that pass
/// and dereference to bit-identical slices produce bit-identical stage
/// executions (the property `bench_runtime` and the value-layer
/// proptests gate on).
pub fn check_args(stage: &str, spec: &StageSpec, args: &[ValueRef]) -> anyhow::Result<()> {
    anyhow::ensure!(
        args.len() == spec.args.len(),
        "{stage}: expected {} args, got {}",
        spec.args.len(),
        args.len()
    );
    for (a, s) in args.iter().zip(&spec.args) {
        anyhow::ensure!(
            a.len() == s.numel(),
            "{stage}: arg '{}' expected {} elems, got {}",
            s.name,
            s.numel(),
            a.len()
        );
        anyhow::ensure!(
            a.dtype() == s.dtype,
            "{stage}: arg '{}' dtype mismatch (spec {}, got {})",
            s.name,
            s.dtype,
            a.dtype()
        );
    }
    Ok(())
}

/// Validate caller-provided result destinations for
/// [`Runtime::run_into`]: either no destinations at all, or one slot
/// per result, with every redirected slot f32-typed and exactly sized.
pub fn check_dests(
    stage: &str,
    spec: &StageSpec,
    dests: &[Option<&mut [f32]>],
) -> anyhow::Result<()> {
    anyhow::ensure!(
        dests.is_empty() || dests.len() == spec.results.len(),
        "{stage}: {} result destinations for {} results",
        dests.len(),
        spec.results.len()
    );
    for (d, r) in dests.iter().zip(&spec.results) {
        if let Some(dst) = d {
            anyhow::ensure!(
                r.dtype == "f32",
                "{stage}: result '{}' is {}, only f32 results can be redirected",
                r.name,
                r.dtype
            );
            anyhow::ensure!(
                dst.len() == r.numel(),
                "{stage}: result '{}' destination holds {} elems, expected {}",
                r.name,
                dst.len(),
                r.numel()
            );
        }
    }
    Ok(())
}

/// Compiled-stage cache over one PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    compiled: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Load the manifest for one exported config directory
    /// (`artifacts/<config>/`).
    pub fn load(artifacts_dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(&artifacts_dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("pjrt cpu client: {e}"))?;
        Ok(Self {
            client,
            manifest,
            dir: artifacts_dir.to_path_buf(),
            compiled: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executable(
        &self,
        stage: &str,
    ) -> anyhow::Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.compiled.lock().unwrap().get(stage) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.stage(stage)?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {stage}: {e}"))?,
        );
        self.compiled.lock().unwrap().insert(stage.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile every stage (pays all XLA compile time up front).
    pub fn warmup(&self) -> anyhow::Result<()> {
        for n in self.manifest.stage_names() {
            self.executable(&n)?;
        }
        Ok(())
    }

    /// Execute a stage.  `args` must match the manifest's arg order,
    /// shapes, and dtypes; each argument's slice uploads verbatim (see
    /// the module docs for the zero-copy contract).  Results come back
    /// owned, in manifest result order.
    pub fn run(&self, stage: &str, args: &[ValueRef]) -> anyhow::Result<Vec<Value>> {
        self.run_into(stage, args, &mut [])
    }

    /// [`Self::run`] with optional caller-provided f32 result
    /// destinations — typically lease views, so a result lands in
    /// pinned memory ready for the next upload.  `dests` is empty (all
    /// results owned) or one slot per result; a `Some(dst)` slot gets
    /// the result copied into `dst` and an empty placeholder
    /// (`Value::F32(vec![])`) in the returned vector.  All-or-nothing:
    /// destinations are written only after *every* result downloaded
    /// and validated, so on `Err` the caller's staging is untouched.
    pub fn run_into(
        &self,
        stage: &str,
        args: &[ValueRef],
        dests: &mut [Option<&mut [f32]>],
    ) -> anyhow::Result<Vec<Value>> {
        // spec is borrowed from the manifest — no per-call clone — and
        // all validation runs before a single byte moves
        let spec = self.manifest.stage(stage)?;
        check_args(stage, spec, args)?;
        check_dests(stage, spec, dests)?;
        // resolve the executable (and pay any compile + cache-lock
        // cost) before the upload loop, so the lock is never held
        // while host buffers stream to the device
        let exe = self.executable(stage)?;
        // Inputs go through caller-owned PjRtBuffers + execute_b: the
        // crate's literal-taking execute() leaks every input device
        // buffer at the C layer (xla_rs.cc `buffer.release()` without a
        // matching free — ~50 MB/step at tiny25m scale), and the
        // host-buffer path also skips one literal copy (§Perf).
        let mut buffers = Vec::with_capacity(args.len());
        for (a, s) in args.iter().zip(&spec.args) {
            let buf = match *a {
                ValueRef::F32(v) => self.client.buffer_from_host_buffer(v, &s.shape, None),
                ValueRef::I32(v) => self.client.buffer_from_host_buffer(v, &s.shape, None),
            }
            .map_err(|e| anyhow::anyhow!("upload {}: {e}", s.name))?;
            buffers.push(buf);
        }
        let result = exe
            .execute_b::<xla::PjRtBuffer>(&buffers)
            .map_err(|e| anyhow::anyhow!("execute {stage}: {e}"))?;
        drop(buffers); // device inputs freed eagerly
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {stage}: {e}"))?;
        // stages are lowered with return_tuple=True
        let parts = out_lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {stage}: {e}"))?;
        anyhow::ensure!(
            parts.len() == spec.results.len(),
            "{stage}: expected {} results, got {}",
            spec.results.len(),
            parts.len()
        );
        let mut out = Vec::with_capacity(parts.len());
        for (lit, r) in parts.into_iter().zip(&spec.results) {
            let v = match r.dtype.as_str() {
                "f32" => Value::F32(
                    lit.to_vec::<f32>()
                        .map_err(|e| anyhow::anyhow!("read {}: {e}", r.name))?,
                ),
                "i32" => Value::I32(
                    lit.to_vec::<i32>()
                        .map_err(|e| anyhow::anyhow!("read {}: {e}", r.name))?,
                ),
                other => anyhow::bail!("unsupported result dtype {other}"),
            };
            anyhow::ensure!(
                v.len() == r.numel(),
                "{stage}: result '{}' expected {} elems, got {}",
                r.name,
                r.numel(),
                v.len()
            );
            out.push(v);
        }
        // every result downloaded and validated — only now touch the
        // caller's destinations, so an error above never leaves a
        // lease half-updated with mixed-generation bytes
        for (i, d) in dests.iter_mut().enumerate() {
            if let Some(dst) = d {
                let owned = std::mem::replace(&mut out[i], Value::F32(Vec::new()));
                let v = owned.into_f32().expect("check_dests admits f32 results only");
                dst.copy_from_slice(&v);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bufpool::test_util::test_arena;
    use crate::pinned::{Cat, Mode};
    use crate::prop_assert;
    use crate::util::proptest::{check, Config};

    fn spec_of(shapes: &[(&str, Vec<usize>, &str)]) -> StageSpec {
        StageSpec {
            name: "stage".into(),
            file: String::new(),
            args: shapes
                .iter()
                .map(|(n, s, d)| ArgSpec {
                    name: n.to_string(),
                    shape: s.clone(),
                    dtype: d.to_string(),
                })
                .collect(),
            results: vec![
                ArgSpec { name: "r0".into(), shape: vec![4], dtype: "f32".into() },
                ArgSpec { name: "r1".into(), shape: vec![2], dtype: "i32".into() },
            ],
        }
    }

    #[test]
    fn check_args_accepts_matching_and_rejects_mismatches() {
        let spec = spec_of(&[("x", vec![2, 3], "f32"), ("ids", vec![4], "i32")]);
        let x = vec![0.5f32; 6];
        let ids = vec![1i32; 4];
        let good = [ValueRef::F32(&x), ValueRef::I32(&ids)];
        check_args("stage", &spec, &good).unwrap();
        // arity
        assert!(check_args("stage", &spec, &good[..1]).is_err());
        // numel
        let short = vec![0.5f32; 5];
        assert!(check_args("stage", &spec, &[ValueRef::F32(&short), ValueRef::I32(&ids)])
            .is_err());
        // dtype
        let as_f32 = vec![0.5f32; 4];
        assert!(check_args("stage", &spec, &[ValueRef::F32(&x), ValueRef::F32(&as_f32)])
            .is_err());
    }

    #[test]
    fn check_dests_validates_arity_dtype_and_len() {
        let spec = spec_of(&[("x", vec![1], "f32")]);
        let mut a = [0f32; 4];
        let mut b = [0f32; 3];
        check_dests("stage", &spec, &[]).unwrap();
        check_dests("stage", &spec, &[Some(&mut a), None]).unwrap();
        // arity: one slot for two results
        {
            let mut a = [0f32; 4];
            assert!(check_dests("stage", &spec, &[Some(&mut a)]).is_err());
        }
        // wrong length
        assert!(check_dests("stage", &spec, &[Some(&mut b), None]).is_err());
        // i32 result cannot be redirected
        {
            let mut a = [0f32; 4];
            let mut c = [0f32; 2];
            assert!(check_dests("stage", &spec, &[Some(&mut a), Some(&mut c)]).is_err());
        }
    }

    #[test]
    fn prop_lease_views_and_owned_args_are_bit_identical_at_the_boundary() {
        // The upload loop consumes exactly `ValueRef::as_f32()` — so
        // two argument lists that pass `check_args` and dereference to
        // equal bits are indistinguishable to the PJRT client, and the
        // stage outputs are bit-identical.  This proptest drives ragged
        // shapes and aliased views of one lease through that seam.
        check("runtime-zero-copy", Config { cases: 40, ..Default::default() }, |rng, size| {
            let n_args = rng.range(1, 6);
            let lens: Vec<usize> =
                (0..n_args).map(|_| rng.range(1, (size * 8).max(2))).collect();
            let total: usize = lens.iter().sum();
            let arena = test_arena(Mode::Real);
            let mut lease = arena
                .lease(total * 4, Cat::SwapBuf)
                .map_err(|e| e.to_string())?;
            let vals: Vec<f32> = (0..total)
                .map(|_| {
                    // include non-finite bit patterns: identity must be
                    // bitwise, not numeric
                    match rng.below(16) {
                        0 => f32::NAN,
                        1 => f32::INFINITY,
                        _ => rng.normal() as f32,
                    }
                })
                .collect();
            lease.as_f32_mut().copy_from_slice(&vals);
            let shared = lease.into_shared();

            let mut off = 0usize;
            let mut owned: Vec<Value> = Vec::new();
            let mut views: Vec<TensorBuf> = Vec::new();
            let mut spec_args = Vec::new();
            for (i, &len) in lens.iter().enumerate() {
                // occasionally alias an earlier window instead of
                // advancing (many views, one lease; overlap allowed)
                let my_off = if i > 0 && rng.next_f64() < 0.25 {
                    rng.below(total - len + 1)
                } else {
                    let o = off;
                    off += len;
                    o
                };
                owned.push(Value::F32(vals[my_off..my_off + len].to_vec()));
                views.push(
                    TensorBuf::view(&shared, my_off, len).map_err(|e| e.to_string())?,
                );
                spec_args.push(ArgSpec {
                    name: format!("a{i}"),
                    shape: vec![len],
                    dtype: "f32".into(),
                });
            }
            let spec = StageSpec {
                name: "stage".into(),
                file: String::new(),
                args: spec_args,
                results: vec![],
            };
            let owned_refs: Vec<ValueRef> = owned.iter().map(Value::as_value).collect();
            let view_refs: Vec<ValueRef> =
                views.iter().map(TensorBuf::as_value).collect();
            check_args("stage", &spec, &owned_refs).map_err(|e| e.to_string())?;
            check_args("stage", &spec, &view_refs).map_err(|e| e.to_string())?;
            for (i, (o, v)) in owned_refs.iter().zip(&view_refs).enumerate() {
                let ob = o.as_f32().map_err(|e| e.to_string())?;
                let vb = v.as_f32().map_err(|e| e.to_string())?;
                prop_assert!(ob.len() == vb.len(), "arg {i} length diverged");
                prop_assert!(
                    ob.iter().zip(vb).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "arg {i}: lease view bytes diverged from owned"
                );
            }
            Ok(())
        });
    }
}
