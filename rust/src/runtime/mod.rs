//! PJRT runtime: load AOT artifacts, compile once, execute from Rust.
//!
//! The request path is Rust-only: `make artifacts` ran Python once to
//! lower every L1/L2 stage to HLO *text* (xla_extension 0.5.1 rejects
//! jax≥0.5's 64-bit-id serialized protos; the text parser reassigns
//! ids).  Here each stage is parsed, compiled on the PJRT CPU client,
//! cached, and invoked with `Literal` marshaling.

pub mod manifest;

pub use manifest::{ArgSpec, Manifest, StageSpec};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// A host-side tensor crossing the PJRT boundary.
#[derive(Debug, Clone)]
pub enum Value {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Value {
    pub fn len(&self) -> usize {
        match self {
            Value::F32(v) => v.len(),
            Value::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            Value::F32(v) => Ok(v),
            Value::I32(_) => anyhow::bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn into_f32(self) -> anyhow::Result<Vec<f32>> {
        match self {
            Value::F32(v) => Ok(v),
            Value::I32(_) => anyhow::bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match self {
            Value::I32(v) => Ok(v),
            Value::F32(_) => anyhow::bail!("expected i32 tensor, got f32"),
        }
    }
}

/// Compiled-stage cache over one PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    compiled: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Load the manifest for one exported config directory
    /// (`artifacts/<config>/`).
    pub fn load(artifacts_dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(&artifacts_dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("pjrt cpu client: {e}"))?;
        Ok(Self {
            client,
            manifest,
            dir: artifacts_dir.to_path_buf(),
            compiled: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executable(
        &self,
        stage: &str,
    ) -> anyhow::Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.compiled.lock().unwrap().get(stage) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.stage(stage)?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {stage}: {e}"))?,
        );
        self.compiled.lock().unwrap().insert(stage.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile every stage (pays all XLA compile time up front).
    pub fn warmup(&self) -> anyhow::Result<()> {
        for n in self.manifest.stage_names() {
            self.executable(&n)?;
        }
        Ok(())
    }

    /// Execute a stage. `args` must match the manifest's arg order,
    /// shapes, and dtypes; results come back in manifest result order.
    pub fn run(&self, stage: &str, args: &[Value]) -> anyhow::Result<Vec<Value>> {
        let spec = self.manifest.stage(stage)?.clone();
        anyhow::ensure!(
            args.len() == spec.args.len(),
            "{stage}: expected {} args, got {}",
            spec.args.len(),
            args.len()
        );
        // Inputs go through caller-owned PjRtBuffers + execute_b: the
        // crate's literal-taking execute() leaks every input device
        // buffer at the C layer (xla_rs.cc `buffer.release()` without a
        // matching free — ~50 MB/step at tiny25m scale), and the
        // host-buffer path also skips one literal copy (§Perf).
        let mut buffers = Vec::with_capacity(args.len());
        for (a, s) in args.iter().zip(&spec.args) {
            anyhow::ensure!(
                a.len() == s.numel(),
                "{stage}: arg '{}' expected {} elems, got {}",
                s.name,
                s.numel(),
                a.len()
            );
            let buf = match (a, s.dtype.as_str()) {
                (Value::F32(v), "f32") => self
                    .client
                    .buffer_from_host_buffer(v, &s.shape, None)
                    .map_err(|e| anyhow::anyhow!("upload {}: {e}", s.name))?,
                (Value::I32(v), "i32") => self
                    .client
                    .buffer_from_host_buffer(v, &s.shape, None)
                    .map_err(|e| anyhow::anyhow!("upload {}: {e}", s.name))?,
                _ => anyhow::bail!("{stage}: arg '{}' dtype mismatch", s.name),
            };
            buffers.push(buf);
        }
        let exe = self.executable(stage)?;
        let result = exe
            .execute_b::<xla::PjRtBuffer>(&buffers)
            .map_err(|e| anyhow::anyhow!("execute {stage}: {e}"))?;
        drop(buffers); // device inputs freed eagerly
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {stage}: {e}"))?;
        // stages are lowered with return_tuple=True
        let parts = out_lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {stage}: {e}"))?;
        anyhow::ensure!(
            parts.len() == spec.results.len(),
            "{stage}: expected {} results, got {}",
            spec.results.len(),
            parts.len()
        );
        let mut out = Vec::with_capacity(parts.len());
        for (lit, r) in parts.into_iter().zip(&spec.results) {
            let v = match r.dtype.as_str() {
                "f32" => Value::F32(
                    lit.to_vec::<f32>()
                        .map_err(|e| anyhow::anyhow!("read {}: {e}", r.name))?,
                ),
                "i32" => Value::I32(
                    lit.to_vec::<i32>()
                        .map_err(|e| anyhow::anyhow!("read {}: {e}", r.name))?,
                ),
                other => anyhow::bail!("unsupported result dtype {other}"),
            };
            anyhow::ensure!(
                v.len() == r.numel(),
                "{stage}: result '{}' expected {} elems, got {}",
                r.name,
                r.numel(),
                v.len()
            );
            out.push(v);
        }
        Ok(out)
    }
}
