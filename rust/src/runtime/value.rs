//! Host-tensor types at the PJRT boundary.
//!
//! Three tiers, from owning to borrowing:
//!
//! - [`Value`] — an owned tensor (`Vec`-backed).  The *result* type:
//!   PJRT literal downloads materialize as owned vectors, and small
//!   caller-built tensors (token batches, the loss-scale scalar) stay
//!   owned too.
//! - [`TensorBuf`] — an owned tensor **or** a shared read-only view
//!   into a [`PinnedArena`](crate::pinned::PinnedArena) lease
//!   ([`F32View`]: `Arc<Lease>` + element offset/len, so one lease can
//!   back many tensor views).  The *storage* type producers hand to
//!   consumers: a swapper fetch, an activation-checkpoint fetch, a
//!   scratch buffer.
//! - [`ValueRef`] — a borrowed typed slice.  The *argument* type:
//!   [`Runtime::run`](super::Runtime::run) takes `&[ValueRef]` and
//!   uploads each slice verbatim, so an argument that resolves into
//!   lease memory crosses the boundary with **zero fp32 host-to-host
//!   copies** between NVMe fetch and PJRT upload.
//!
//! ## Aliasing contract
//!
//! Who may mutate a lease while views exist: **nobody**.  A producer
//! fills a lease through `&mut Lease` (unique ownership), then freezes
//! it with [`Lease::into_shared`]; every [`F32View`] holds an
//! `Arc<Lease>` and only ever takes `&Lease`, so the type system makes
//! writes impossible until the last view drops and the extent returns
//! to the arena.  Views of one lease may overlap freely — they are all
//! read-only.
//!
//! Producers that cannot get a lease (budget refusal, Virtual-mode
//! arena) degrade to the owned tier and charge the staged bytes to a
//! [`HostCopyMeter`](crate::metrics::HostCopyMeter) — bit-identical
//! data, just not zero-copy, surfaced per step as
//! `StepMetrics::host_copy_bytes`.

use std::sync::Arc;

use crate::metrics::HostCopyMeter;
use crate::pinned::{Cat, Lease, PinnedArena};

/// An owned host-side tensor crossing the PJRT boundary (results, and
/// caller-built inputs).
#[derive(Debug, Clone)]
pub enum Value {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Value {
    pub fn len(&self) -> usize {
        match self {
            Value::F32(v) => v.len(),
            Value::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            Value::F32(v) => Ok(v),
            Value::I32(_) => anyhow::bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn into_f32(self) -> anyhow::Result<Vec<f32>> {
        match self {
            Value::F32(v) => Ok(v),
            Value::I32(_) => anyhow::bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match self {
            Value::I32(v) => Ok(v),
            Value::F32(_) => anyhow::bail!("expected i32 tensor, got f32"),
        }
    }

    /// Borrow as a stage argument.
    pub fn as_value(&self) -> ValueRef<'_> {
        match self {
            Value::F32(v) => ValueRef::F32(v),
            Value::I32(v) => ValueRef::I32(v),
        }
    }
}

/// A borrowed stage argument: the typed slice the PJRT client uploads
/// verbatim.  `Copy`, so argument lists are cheap to build and rebuild.
#[derive(Debug, Clone, Copy)]
pub enum ValueRef<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl ValueRef<'_> {
    pub fn len(&self) -> usize {
        match self {
            ValueRef::F32(v) => v.len(),
            ValueRef::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Manifest dtype string this argument satisfies.
    pub fn dtype(&self) -> &'static str {
        match self {
            ValueRef::F32(_) => "f32",
            ValueRef::I32(_) => "i32",
        }
    }

    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            ValueRef::F32(v) => Ok(v),
            ValueRef::I32(_) => anyhow::bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match self {
            ValueRef::I32(v) => Ok(v),
            ValueRef::F32(_) => anyhow::bail!("expected i32 tensor, got f32"),
        }
    }
}

impl<'a> From<&'a Value> for ValueRef<'a> {
    fn from(v: &'a Value) -> Self {
        v.as_value()
    }
}

impl<'a> From<&'a TensorBuf> for ValueRef<'a> {
    fn from(b: &'a TensorBuf) -> Self {
        b.as_value()
    }
}

/// A shared read-only f32 window into one pinned lease: `[off, off +
/// len)` in elements.  Cloning shares the lease; the extent recycles
/// when the last clone drops.
#[derive(Clone)]
pub struct F32View {
    lease: Arc<Lease>,
    off: usize,
    len: usize,
}

impl F32View {
    /// View `len` elements of `lease` starting at element `off`.
    /// Errors on a *short lease* (window past the leased span), on a
    /// non-f32-sized lease, and on Virtual-mode leases (no storage to
    /// view) — the same construction-time checks as
    /// [`TensorBuf::from_lease`], so a bad lease never reaches
    /// `Lease::as_f32`.
    pub fn new(lease: &Arc<Lease>, off: usize, len: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(
            !lease.is_virtual(),
            "cannot view a Virtual-mode lease (no backing storage)"
        );
        anyhow::ensure!(
            lease.bytes_requested() % 4 == 0,
            "f32 view over a lease of {} bytes (not a multiple of 4)",
            lease.bytes_requested()
        );
        let avail = lease.len_f32();
        anyhow::ensure!(
            off.checked_add(len).is_some_and(|end| end <= avail),
            "short lease: f32 view [{off}, {off}+{len}) exceeds the {avail}-element span"
        );
        Ok(Self { lease: Arc::clone(lease), off, len })
    }

    pub fn as_f32(&self) -> &[f32] {
        &self.lease.as_f32()[self.off..self.off + self.len]
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl std::fmt::Debug for F32View {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "F32View {{ off: {}, len: {} }}", self.off, self.len)
    }
}

/// Lease-aware host f32 tensor: what producers hand to the consumer
/// that builds a stage's argument list.  Either tier resolves to the
/// same bytes through [`Self::as_value`]; only the `View` tier is
/// zero-copy.  Deliberately f32-only: the pipeline's i32 tensors
/// (token/label batches) are tiny caller-built vectors that stay
/// [`Value`]/[`ValueRef::I32`] — giving them a lease tier would add a
/// variant no producer constructs.
#[derive(Debug, Clone)]
pub enum TensorBuf {
    F32(Vec<f32>),
    View(F32View),
}

impl TensorBuf {
    /// Freeze a whole (filled) lease into a view-backed tensor.  The
    /// lease must be real and f32-sized.
    pub fn from_lease(lease: Lease) -> anyhow::Result<Self> {
        anyhow::ensure!(
            !lease.is_virtual(),
            "cannot view a Virtual-mode lease (no backing storage)"
        );
        anyhow::ensure!(
            lease.bytes_requested() % 4 == 0,
            "f32 tensor over a lease of {} bytes (not a multiple of 4)",
            lease.bytes_requested()
        );
        let shared = lease.into_shared();
        let len = shared.len_f32();
        Ok(TensorBuf::View(F32View { lease: shared, off: 0, len }))
    }

    /// View a window of an already-shared lease (one lease, many
    /// tensors).
    pub fn view(lease: &Arc<Lease>, off: usize, len: usize) -> anyhow::Result<Self> {
        Ok(TensorBuf::View(F32View::new(lease, off, len)?))
    }

    pub fn len(&self) -> usize {
        match self {
            TensorBuf::F32(v) => v.len(),
            TensorBuf::View(w) => w.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether this tensor is a zero-copy lease view.
    pub fn is_view(&self) -> bool {
        matches!(self, TensorBuf::View(_))
    }

    /// Borrow as a stage argument — the boundary crossing itself.
    pub fn as_value(&self) -> ValueRef<'_> {
        match self {
            TensorBuf::F32(v) => ValueRef::F32(v),
            TensorBuf::View(w) => ValueRef::F32(w.as_f32()),
        }
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            TensorBuf::F32(v) => v,
            TensorBuf::View(w) => w.as_f32(),
        }
    }
}

impl From<Vec<f32>> for TensorBuf {
    fn from(v: Vec<f32>) -> Self {
        TensorBuf::F32(v)
    }
}

/// Fill-then-freeze staging destination for producers that decode into
/// either a pinned lease (the zero-copy path) or an owned fallback
/// vector (budget refusal / Virtual arena — the caller charges the
/// meter).  Both tiers expose the same `&mut [f32]` while filling and
/// freeze into a [`TensorBuf`] when done.
pub enum F32Staging {
    Lease(Lease),
    Owned(Vec<f32>),
}

impl F32Staging {
    /// Take an `n`-element staging destination from `arena` under
    /// `cat`: a pinned lease when the arena grants one (the zero-copy
    /// tier), else an owned scratch vector with the staged bytes
    /// charged to `meter`.  *The* lease-else-owned degradation policy
    /// — every f32 producer (swapper upconvert, activation fetch)
    /// takes its destination here so the policy cannot drift between
    /// call sites.
    pub fn take(
        arena: &PinnedArena,
        cat: Cat,
        n: usize,
        meter: &HostCopyMeter,
    ) -> Self {
        match arena.lease(n * 4, cat) {
            Ok(l) if !l.is_virtual() => F32Staging::Lease(l),
            _ => {
                meter.add(n * 4);
                F32Staging::Owned(arena.take_f32(n, cat))
            }
        }
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        match self {
            F32Staging::Lease(l) => l.as_f32_mut(),
            F32Staging::Owned(v) => v,
        }
    }

    pub fn freeze(self) -> TensorBuf {
        match self {
            F32Staging::Lease(l) => {
                TensorBuf::from_lease(l).expect("staging lease is real and f32-sized")
            }
            F32Staging::Owned(v) => TensorBuf::F32(v),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bufpool::test_util::test_arena;
    use crate::pinned::{Cat, Mode};

    #[test]
    fn owned_and_view_tensors_resolve_to_identical_args() {
        let a = test_arena(Mode::Real);
        let mut l = a.lease(16 * 4, Cat::SwapBuf).unwrap();
        for (i, x) in l.as_f32_mut().iter_mut().enumerate() {
            *x = i as f32 * 0.5;
        }
        let owned = TensorBuf::F32((0..16).map(|i| i as f32 * 0.5).collect());
        let view = TensorBuf::from_lease(l).unwrap();
        assert!(view.is_view() && !owned.is_view());
        let (a1, a2) = (owned.as_value(), view.as_value());
        assert_eq!(a1.dtype(), "f32");
        assert_eq!(a1.len(), a2.len());
        assert_eq!(a1.as_f32().unwrap(), a2.as_f32().unwrap());
        assert_eq!(owned.as_f32(), view.as_f32());
    }

    #[test]
    fn one_lease_backs_many_views_including_aliased_ones() {
        let a = test_arena(Mode::Real);
        let mut l = a.lease(32 * 4, Cat::SwapBuf).unwrap();
        for (i, x) in l.as_f32_mut().iter_mut().enumerate() {
            *x = i as f32;
        }
        let shared = l.into_shared();
        let head = TensorBuf::view(&shared, 0, 8).unwrap();
        let tail = TensorBuf::view(&shared, 24, 8).unwrap();
        let alias = TensorBuf::view(&shared, 4, 8).unwrap(); // overlaps head
        assert_eq!(head.as_f32()[7], 7.0);
        assert_eq!(tail.as_f32()[0], 24.0);
        assert_eq!(alias.as_f32()[0], 4.0);
        drop(shared);
        // views keep the lease alive after the original Arc drops
        assert_eq!(head.as_f32()[0], 0.0);
        drop((head, tail, alias));
        assert_eq!(a.stats().requested_bytes, 0, "extent not released");
    }

    #[test]
    fn short_lease_and_virtual_lease_are_typed_errors() {
        let a = test_arena(Mode::Real);
        let shared = a.lease(8 * 4, Cat::SwapBuf).unwrap().into_shared();
        let err = TensorBuf::view(&shared, 4, 8).unwrap_err();
        assert!(err.to_string().contains("short lease"), "{err}");
        assert!(TensorBuf::view(&shared, usize::MAX, 2).is_err(), "offset overflow");
        // non-f32-sized leases are rejected at construction, matching
        // from_lease (never deferred to Lease::as_f32)
        let odd = a.lease(10, Cat::SwapBuf).unwrap().into_shared();
        assert!(TensorBuf::view(&odd, 0, 1).is_err(), "odd-sized lease accepted");
        let v = test_arena(Mode::Virtual);
        let vl = v.lease(64, Cat::SwapBuf).unwrap();
        assert!(TensorBuf::from_lease(vl).to_err_string().contains("Virtual"));
    }

    #[test]
    fn dtype_mismatch_surfaces_through_valueref() {
        let t = Value::I32(vec![1, 2, 3]);
        assert!(t.as_value().as_f32().is_err());
        assert_eq!(t.as_value().dtype(), "i32");
        assert_eq!(t.as_value().as_i32().unwrap(), &[1, 2, 3]);
        assert!(ValueRef::F32(&[1.0]).as_i32().is_err());
    }

    #[test]
    fn staging_freezes_into_the_matching_tier() {
        let a = test_arena(Mode::Real);
        let mut s = F32Staging::Lease(a.lease(4 * 4, Cat::SwapBuf).unwrap());
        s.as_mut_slice().copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let b = s.freeze();
        assert!(b.is_view());
        assert_eq!(b.as_f32(), &[1.0, 2.0, 3.0, 4.0]);
        let mut s = F32Staging::Owned(vec![0.0; 2]);
        s.as_mut_slice()[1] = 9.0;
        let b = s.freeze();
        assert!(!b.is_view());
        assert_eq!(b.as_f32(), &[0.0, 9.0]);
    }

    trait ToErrString {
        fn to_err_string(self) -> String;
    }

    impl<T> ToErrString for anyhow::Result<T> {
        fn to_err_string(self) -> String {
            self.err().map(|e| e.to_string()).unwrap_or_default()
        }
    }
}
