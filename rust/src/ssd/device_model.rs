//! Analytic NVMe device model — the physics behind Fig. 14's curves.
//!
//! Container-backed files cannot exhibit real NVMe behaviour (SLC-cache
//! burst absorption, destaging to NAND, deep queues), so full-scale
//! projections use this model, parameterized by a `HardwareSpec`:
//!
//! **Write path** (Fig. 14(a)/(b)):
//! - *direct engine*: `t = t_submit + size / bw_eff(size)`, where
//!   `bw_eff` starts at the cache-absorption rate for transfers that
//!   fit the SLC/DRAM cache and converges to the sustained NAND rate as
//!   the written volume grows — the paper's "decreasing trend in
//!   MemAscend's write bandwidth".
//! - *filesystem baseline*: adds a fixed host-side cost per operation
//!   (path resolution + metadata + journaling + RAID merge) and a
//!   per-extent allocation cost, so small writes are overhead-dominated
//!   and bandwidth *rises* with size — "the contrasting shapes of the
//!   two curves".
//!
//! **Read path** (Fig. 14(c)/(d)): both engines see NAND read rates;
//! the filesystem adds lookup costs and *variance* (RAID-level merges),
//! the direct path is flat.

use crate::config::HardwareSpec;

/// Cache-absorbed write speed multiplier over sustained NAND rate.
const CACHE_BOOST: f64 = 4.0;
/// Host-side submission cost for a raw AIO request, seconds.
const T_SUBMIT: f64 = 8e-6;
/// Filesystem fixed cost per write op: open + resolve + metadata.
const T_FS_WRITE_OP: f64 = 650e-6;
/// Filesystem fixed cost per read op.
const T_FS_READ_OP: f64 = 120e-6;
/// Journal/allocation cost per MiB of *newly allocated* space.
const T_FS_ALLOC_PER_MIB: f64 = 35e-6;

#[derive(Debug, Clone)]
pub struct DeviceModel {
    pub hw: HardwareSpec,
}

impl DeviceModel {
    pub fn new(hw: &HardwareSpec) -> Self {
        Self { hw: hw.clone() }
    }

    fn gib(bytes: u64) -> f64 {
        bytes as f64 / (1u64 << 30) as f64
    }

    /// Effective aggregate write bandwidth (GiB/s) for one transfer of
    /// `bytes`, including SLC-cache absorption and destaging blend.
    ///
    /// Under a sustained benchmark the cache never fully drains between
    /// transfers, so only a small fraction of the nominal SLC capacity
    /// is available per op — modeled as an exponential decay of the
    /// cache boost with transfer size (calibrated so the paper's 2 MiB
    /// and 3.1 GB write latencies both land; see Fig. 14 bench).
    pub fn write_bw_eff(&self, bytes: u64) -> f64 {
        let sustained = self.hw.ssd_agg_write_gibs();
        // steady-state usable cache: ~4% of nominal SLC capacity
        let eff_cache = (self.hw.ssd_cache_gib * self.hw.ssds as f64 * 0.04).max(0.05);
        let size = Self::gib(bytes);
        let boost = 1.0 + (CACHE_BOOST - 1.0) * (-size / eff_cache).exp();
        sustained * boost
    }

    /// Direct-engine write latency (seconds) for one tensor.
    pub fn direct_write_lat(&self, bytes: u64) -> f64 {
        let stripes = self.hw.ssds.max(1) as f64;
        T_SUBMIT * stripes
            + self.hw.ssd_lat_us * 1e-6
            + Self::gib(bytes) / self.write_bw_eff(bytes)
    }

    /// Filesystem write latency (seconds); `fresh` = first allocation.
    pub fn fs_write_lat(&self, bytes: u64, fresh: bool) -> f64 {
        let alloc = if fresh {
            T_FS_ALLOC_PER_MIB * (bytes as f64 / (1u64 << 20) as f64)
        } else {
            0.0
        };
        // the fs path throttles effective bandwidth (journaled writes,
        // RAID merge on the critical path)
        let bw = self.hw.ssd_agg_write_gibs() * 0.85;
        T_FS_WRITE_OP + alloc + self.hw.ssd_lat_us * 1e-6 + Self::gib(bytes) / bw
    }

    pub fn direct_read_lat(&self, bytes: u64) -> f64 {
        T_SUBMIT * self.hw.ssds.max(1) as f64
            + self.hw.ssd_lat_us * 1e-6
            + Self::gib(bytes) / self.hw.ssd_agg_read_gibs()
    }

    pub fn fs_read_lat(&self, bytes: u64) -> f64 {
        T_FS_READ_OP
            + self.hw.ssd_lat_us * 1e-6
            + Self::gib(bytes) / (self.hw.ssd_agg_read_gibs() * 0.97)
    }

    /// Observed bandwidth (GiB/s) from a latency function.
    pub fn bw_of(bytes: u64, lat: f64) -> f64 {
        Self::gib(bytes) / lat
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::CONFIG2;

    fn model() -> DeviceModel {
        DeviceModel::new(&CONFIG2)
    }

    #[test]
    fn small_writes_direct_beats_fs_heavily() {
        // paper: 2 MiB tensor, 988us (fs) vs 219us (direct) — 4.5x
        let m = model();
        let bytes = 2_097_152;
        let fs = m.fs_write_lat(bytes, false);
        let direct = m.direct_write_lat(bytes);
        let speedup = fs / direct;
        assert!(
            (2.0..8.0).contains(&speedup),
            "speedup {speedup} out of paper ballpark"
        );
    }

    #[test]
    fn large_writes_converge() {
        // paper: 3.1 GB tensor, 304ms vs 266ms — ~1.14x
        let m = model();
        let bytes = 3_114_270_720;
        let ratio = m.fs_write_lat(bytes, false) / m.direct_write_lat(bytes);
        assert!((1.0..1.6).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn direct_write_bw_decreases_with_size() {
        // SLC cache absorbs small bursts -> destaging dominates later
        let m = model();
        let small = DeviceModel::bw_of(1 << 24, m.direct_write_lat(1 << 24));
        let large =
            DeviceModel::bw_of(60 << 30, m.direct_write_lat(60u64 << 30));
        assert!(small > large * 1.5, "small {small} vs large {large}");
    }

    #[test]
    fn fs_write_bw_increases_with_size() {
        let m = model();
        let small = DeviceModel::bw_of(1 << 21, m.fs_write_lat(1 << 21, false));
        let large = DeviceModel::bw_of(1 << 30, m.fs_write_lat(1 << 30, false));
        assert!(large > small * 2.0, "small {small} vs large {large}");
    }

    #[test]
    fn reads_are_comparable() {
        // paper: "both methods achieve similar average read bandwidth"
        let m = model();
        let b = 1u64 << 28;
        let fs = DeviceModel::bw_of(b, m.fs_read_lat(b));
        let direct = DeviceModel::bw_of(b, m.direct_read_lat(b));
        let ratio = direct / fs;
        assert!((0.9..1.3).contains(&ratio), "ratio {ratio}");
    }
}
