//! MemAscend's direct NVMe engine (§IV-E).
//!
//! The paper bypasses the filesystem entirely: raw AIO requests go to
//! the NVMe driver at logical block addresses handed out by a location
//! allocator, with a tensor-location dictionary mapping tensor keys to
//! (device, LBA, length) extents and requests divided among worker
//! threads so the data is horizontally striped across SSDs ("striping
//! in place of software RAID 0").  A shared offset counter guarantees
//! extents never overlap; the cost of consulting it is "a simple shared
//! memory integer operation that occurs only once per tensor".
//!
//! Here each device is one flat preallocated file standing in for
//! `/dev/nvmeXn1` — all I/O is positional (`pread`/`pwrite`-style via
//! `FileExt`) at 4 KiB-aligned LBAs, with **no** per-tensor file
//! creation, path resolution, or metadata journaling on the data path.
//!
//! Striped transfers run on the async queue layer: every device owns a
//! persistent [`IoExecutor`] (its submission queue — `workers` threads
//! each), and a multi-extent read/write fans its extents out as one
//! job per extent on the owning device's queue via [`io_scope`].
//! Workers receive disjoint slices of the caller's buffer, so there is
//! no locking on the data path and no per-call thread spawn.
//!
//! The tensor-location dictionary is **journaled** to a sidecar file
//! (`dict.json`, written atomically via rename) whenever a *new*
//! tensor is allocated — once per tensor, under the allocation lock,
//! never on the per-transfer data path.  Reopening the engine on an
//! existing root restores the dictionary and the per-device offset
//! counters, which is what makes SSD-resident training state
//! recoverable across a process restart ([`crate::ckpt`]).  `flush`
//! is a real durability barrier here: `fdatasync` on every device
//! file holding one of the key's extents.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};
use std::time::Instant;

use super::queue::{io_scope, IoExecutor};
use super::{IoSnapshot, IoStats, NvmeEngine};
use crate::util::json::Json;

/// Sidecar file the tensor-location dictionary is journaled to.
pub const DICT_FILE: &str = "dict.json";

/// LBA granularity: NVMe logical block = 4 KiB here.
pub const LBA_SIZE: usize = 4096;

/// One stripe extent of a tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Extent {
    pub dev: usize,
    /// Byte offset on the device (LBA * LBA_SIZE).
    pub offset: u64,
    pub len: usize,
}

struct Device {
    file: File,
    /// The location allocator's shared offset counter (bump allocation,
    /// LBA-aligned — the paper's "shared device information structure").
    next_offset: AtomicU64,
    capacity: u64,
    /// Persistent per-device submission queue (the NVMe SQ analog).
    queue: IoExecutor,
}

pub struct DirectEngine {
    devices: Vec<Device>,
    root: PathBuf,
    /// Tensor location dictionary: key -> stripes + logical length.
    dict: RwLock<HashMap<String, (Vec<Extent>, usize)>>,
    /// Round-robin start device for striping fairness.
    next_start: AtomicU64,
    stats: IoStats,
    /// Serializes allocation of a *new* tensor (once per tensor).
    alloc_lock: Mutex<()>,
}

impl DirectEngine {
    /// `root/nvmeN.raw` are the flat device files of `capacity` bytes
    /// each (created sparse). `workers` = I/O worker threads *per
    /// device queue* (persistent, not spawned per call).
    pub fn new(
        root: &Path,
        devices: usize,
        capacity: u64,
        workers: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(devices >= 1 && workers >= 1);
        std::fs::create_dir_all(root)?;
        let devs = (0..devices)
            .map(|i| {
                let file = OpenOptions::new()
                    .create(true)
                    .read(true)
                    .write(true)
                    .truncate(false)
                    .open(root.join(format!("nvme{i}.raw")))?;
                file.set_len(capacity)?; // sparse preallocation
                Ok(Device {
                    file,
                    next_offset: AtomicU64::new(0),
                    capacity,
                    queue: IoExecutor::new(workers),
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let eng = Self {
            devices: devs,
            root: root.to_path_buf(),
            dict: RwLock::new(HashMap::new()),
            next_start: AtomicU64::new(0),
            stats: IoStats::default(),
            alloc_lock: Mutex::new(()),
        };
        eng.load_dict()?;
        Ok(eng)
    }

    /// Restore a journaled tensor-location dictionary (and the offset
    /// counters) from a previous run, if one exists at this root.
    fn load_dict(&self) -> anyhow::Result<()> {
        let path = self.root.join(DICT_FILE);
        let Ok(text) = std::fs::read_to_string(&path) else {
            return Ok(()); // fresh root
        };
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("direct: corrupt {DICT_FILE}: {e}"))?;
        let mut dict = HashMap::new();
        for (key, entry) in j
            .req("tensors")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("direct: {DICT_FILE}: tensors not an object"))?
        {
            let len = entry
                .req("len")?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("direct: {DICT_FILE}: bad len"))?;
            let mut extents = Vec::new();
            for e in entry.req("ext")?.as_arr().unwrap_or(&[]) {
                let t = e
                    .as_arr()
                    .filter(|t| t.len() == 3)
                    .ok_or_else(|| anyhow::anyhow!("direct: {DICT_FILE}: bad extent"))?;
                let dev = t[0].as_usize().unwrap_or(usize::MAX);
                anyhow::ensure!(
                    dev < self.devices.len(),
                    "direct: {DICT_FILE} references device {dev}, \
                     but the engine was opened with {} devices",
                    self.devices.len()
                );
                extents.push(Extent {
                    dev,
                    offset: t[1].as_u64().unwrap_or(0),
                    len: t[2].as_usize().unwrap_or(0),
                });
            }
            dict.insert(key.clone(), (extents, len));
        }
        if let Some(next) = j.get("next").and_then(|n| n.as_arr()) {
            for (d, n) in self.devices.iter().zip(next) {
                d.next_offset
                    .store(n.as_u64().unwrap_or(0), Ordering::Relaxed);
            }
        }
        // belt and braces: never allocate below a restored extent even
        // if the counters in the journal lagged the tensor entries
        for (ext, _) in dict.values() {
            for e in ext {
                let end = e.offset + (e.len.div_ceil(LBA_SIZE) * LBA_SIZE) as u64;
                let d = &self.devices[e.dev];
                d.next_offset.fetch_max(end, Ordering::Relaxed);
            }
        }
        *self.dict.write().unwrap() = dict;
        Ok(())
    }

    /// Journal the dictionary to the sidecar (atomic tmp+rename).
    /// Called under the allocation lock — once per *new* tensor, never
    /// on the transfer path.
    fn persist_dict(&self) -> anyhow::Result<()> {
        let dict = self.dict.read().unwrap();
        let tensors = Json::Obj(
            dict.iter()
                .map(|(k, (ext, len))| {
                    let ext_json: Vec<Json> = ext
                        .iter()
                        .map(|e| {
                            Json::Arr(vec![
                                Json::from(e.dev),
                                Json::from(e.offset),
                                Json::from(e.len),
                            ])
                        })
                        .collect();
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("len", Json::from(*len)),
                            ("ext", Json::Arr(ext_json)),
                        ]),
                    )
                })
                .collect(),
        );
        drop(dict);
        let next: Vec<Json> = self
            .devices
            .iter()
            .map(|d| Json::from(d.next_offset.load(Ordering::Relaxed)))
            .collect();
        let blob = Json::obj(vec![("next", Json::Arr(next)), ("tensors", tensors)]);
        let tmp = self.root.join(format!("{DICT_FILE}.tmp"));
        std::fs::write(&tmp, blob.to_string())?;
        std::fs::rename(&tmp, self.root.join(DICT_FILE))?;
        Ok(())
    }

    /// Allocate striped extents for a new tensor of `len` bytes:
    /// split into `devices` near-equal LBA-aligned portions (the
    /// paper's "dividing the data into equal portions").
    fn allocate(&self, key: &str, len: usize) -> anyhow::Result<Vec<Extent>> {
        let _guard = self.alloc_lock.lock().unwrap();
        // double-check under the lock
        if let Some((ext, stored)) = self.dict.read().unwrap().get(key) {
            anyhow::ensure!(
                *stored == len,
                "direct: size change for '{key}' ({stored} -> {len}) unsupported"
            );
            return Ok(ext.clone());
        }
        let n = self.devices.len();
        let start = self.next_start.fetch_add(1, Ordering::Relaxed) as usize;
        let per = len.div_ceil(n);
        let per_aligned = per.div_ceil(LBA_SIZE) * LBA_SIZE;
        let mut extents = Vec::with_capacity(n);
        let mut remaining = len;
        for i in 0..n {
            if remaining == 0 {
                break;
            }
            let dev = (start + i) % n;
            let this = per.min(remaining);
            let off = self.devices[dev]
                .next_offset
                .fetch_add(per_aligned as u64, Ordering::Relaxed);
            anyhow::ensure!(
                off + per_aligned as u64 <= self.devices[dev].capacity,
                "direct: device {dev} full"
            );
            extents.push(Extent { dev, offset: off, len: this });
            remaining -= this;
        }
        self.dict
            .write()
            .unwrap()
            .insert(key.to_string(), (extents.clone(), len));
        // journal the updated dictionary while the allocation lock is
        // still held — crash after this point loses no location state
        self.persist_dict()?;
        Ok(extents)
    }

    fn lookup(&self, key: &str) -> Option<(Vec<Extent>, usize)> {
        self.dict.read().unwrap().get(key).cloned()
    }

    /// Map logical byte window `[offset, offset+len)` onto the tensor's
    /// extents: (extent, device byte offset, part length) per touched
    /// extent, in logical order.  Extents are stored in logical order,
    /// so this is one forward walk.
    fn window_parts(
        extents: &[Extent],
        offset: usize,
        len: usize,
    ) -> Vec<(Extent, u64, usize)> {
        let mut parts = Vec::new();
        let mut logical = 0usize;
        let end = offset + len;
        for e in extents {
            let e_start = logical;
            let e_end = logical + e.len;
            logical = e_end;
            if e_end <= offset {
                continue;
            }
            if e_start >= end {
                break;
            }
            let lo = offset.max(e_start);
            let hi = end.min(e_end);
            if lo < hi {
                parts.push((*e, e.offset + (lo - e_start) as u64, hi - lo));
            }
        }
        parts
    }
}

impl NvmeEngine for DirectEngine {
    fn write(&self, key: &str, data: &[u8]) -> anyhow::Result<()> {
        let t0 = Instant::now();
        let busy = self.stats.busy_guard();
        let extents = match self.lookup(key) {
            Some((ext, stored)) => {
                anyhow::ensure!(
                    stored == data.len(),
                    "direct: size change for '{key}' unsupported"
                );
                ext
            }
            None => self.allocate(key, data.len())?,
        };
        if extents.len() == 1 {
            let e = &extents[0];
            let _q = self.stats.queue_guard(e.dev);
            self.devices[e.dev].file.write_all_at(data, e.offset)?;
        } else {
            // one job per extent on its device's queue; the running
            // logical offset is carried alongside, never recomputed
            io_scope(|s| {
                let mut logical = 0usize;
                for e in &extents {
                    let chunk = &data[logical..logical + e.len];
                    logical += e.len;
                    let dev = &self.devices[e.dev];
                    let stats = &self.stats;
                    let dev_idx = e.dev;
                    s.submit(&dev.queue, move || {
                        let _q = stats.queue_guard(dev_idx);
                        dev.file.write_all_at(chunk, e.offset)?;
                        Ok(())
                    });
                }
                Ok(())
            })?;
        }
        drop(busy);
        self.stats.record_write(data.len() as u64, t0.elapsed().as_nanos() as u64);
        Ok(())
    }

    fn read(&self, key: &str, out: &mut [u8]) -> anyhow::Result<()> {
        let t0 = Instant::now();
        let busy = self.stats.busy_guard();
        let (extents, stored) = self
            .lookup(key)
            .ok_or_else(|| anyhow::anyhow!("direct: no tensor '{key}'"))?;
        anyhow::ensure!(
            stored == out.len(),
            "direct: '{key}' stored {stored} B, requested {} B",
            out.len()
        );
        let out_len = out.len() as u64;
        if extents.len() == 1 {
            let e = &extents[0];
            let _q = self.stats.queue_guard(e.dev);
            self.devices[e.dev].file.read_exact_at(out, e.offset)?;
        } else {
            // split `out` into one disjoint slice per extent (extent
            // order == logical order); each worker owns its slice
            let mut parts: Vec<(&Extent, &mut [u8])> =
                Vec::with_capacity(extents.len());
            let mut rest = out;
            for e in &extents {
                let (head, tail) = rest.split_at_mut(e.len);
                parts.push((e, head));
                rest = tail;
            }
            io_scope(|s| {
                for (e, slice) in parts {
                    let dev = &self.devices[e.dev];
                    let stats = &self.stats;
                    let dev_idx = e.dev;
                    s.submit(&dev.queue, move || {
                        let _q = stats.queue_guard(dev_idx);
                        dev.file.read_exact_at(slice, e.offset)?;
                        Ok(())
                    });
                }
                Ok(())
            })?;
        }
        drop(busy);
        self.stats.record_read(out_len, t0.elapsed().as_nanos() as u64);
        Ok(())
    }

    fn read_at(&self, key: &str, offset: usize, out: &mut [u8]) -> anyhow::Result<()> {
        let t0 = Instant::now();
        let busy = self.stats.busy_guard();
        let (extents, stored) = self
            .lookup(key)
            .ok_or_else(|| anyhow::anyhow!("direct: no tensor '{key}'"))?;
        anyhow::ensure!(
            offset + out.len() <= stored,
            "direct: ranged read past '{key}' ({offset}+{} > {stored})",
            out.len()
        );
        let out_len = out.len() as u64;
        let parts = Self::window_parts(&extents, offset, out.len());
        if let [(e, dev_off, _)] = parts[..] {
            // common case: a tile inside one extent — positional read,
            // no fan-out
            let _q = self.stats.queue_guard(e.dev);
            self.devices[e.dev].file.read_exact_at(out, dev_off)?;
        } else {
            let mut slices: Vec<(Extent, u64, &mut [u8])> =
                Vec::with_capacity(parts.len());
            let mut rest = out;
            for (e, dev_off, len) in parts {
                let (head, tail) = rest.split_at_mut(len);
                slices.push((e, dev_off, head));
                rest = tail;
            }
            io_scope(|s| {
                for (e, dev_off, slice) in slices {
                    let dev = &self.devices[e.dev];
                    let stats = &self.stats;
                    s.submit(&dev.queue, move || {
                        let _q = stats.queue_guard(e.dev);
                        dev.file.read_exact_at(slice, dev_off)?;
                        Ok(())
                    });
                }
                Ok(())
            })?;
        }
        drop(busy);
        self.stats.record_read(out_len, t0.elapsed().as_nanos() as u64);
        Ok(())
    }

    fn write_at(&self, key: &str, offset: usize, data: &[u8]) -> anyhow::Result<()> {
        let t0 = Instant::now();
        let busy = self.stats.busy_guard();
        let (extents, stored) = self
            .lookup(key)
            .ok_or_else(|| anyhow::anyhow!("direct: no tensor '{key}'"))?;
        anyhow::ensure!(
            offset + data.len() <= stored,
            "direct: ranged write past '{key}' ({offset}+{} > {stored})",
            data.len()
        );
        let parts = Self::window_parts(&extents, offset, data.len());
        if let [(e, dev_off, _)] = parts[..] {
            let _q = self.stats.queue_guard(e.dev);
            self.devices[e.dev].file.write_all_at(data, dev_off)?;
        } else {
            io_scope(|s| {
                let mut logical = 0usize;
                for (e, dev_off, len) in parts {
                    let chunk = &data[logical..logical + len];
                    logical += len;
                    let dev = &self.devices[e.dev];
                    let stats = &self.stats;
                    s.submit(&dev.queue, move || {
                        let _q = stats.queue_guard(e.dev);
                        dev.file.write_all_at(chunk, dev_off)?;
                        Ok(())
                    });
                }
                Ok(())
            })?;
        }
        drop(busy);
        self.stats.record_write(data.len() as u64, t0.elapsed().as_nanos() as u64);
        Ok(())
    }

    fn flush(&self, key: &str) -> anyhow::Result<()> {
        // real durability barrier: fdatasync every device file holding
        // one of the key's extents (absent key -> nothing to flush)
        let Some((extents, _)) = self.lookup(key) else {
            return Ok(());
        };
        let mut devs: Vec<usize> = extents.iter().map(|e| e.dev).collect();
        devs.sort_unstable();
        devs.dedup();
        for d in devs {
            self.devices[d].file.sync_data()?;
        }
        Ok(())
    }

    fn reserve(&self, key: &str, len: usize) -> anyhow::Result<()> {
        // allocation without data movement: the location allocator
        // hands out the extents, the sparse device files read back
        // zeros until tiles land
        match self.lookup(key) {
            Some((_, stored)) => {
                anyhow::ensure!(
                    stored == len,
                    "direct: reserve size change for '{key}' ({stored} -> {len}) unsupported"
                );
                Ok(())
            }
            None => self.allocate(key, len).map(|_| ()),
        }
    }

    fn len_of(&self, key: &str) -> Option<usize> {
        self.lookup(key).map(|(_, l)| l)
    }

    fn stats(&self) -> IoSnapshot {
        self.stats.snapshot()
    }

    fn label(&self) -> &'static str {
        "direct-nvme"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::{check, Config};

    fn mk(tag: &str, devs: usize, workers: usize) -> (DirectEngine, std::path::PathBuf) {
        let dir =
            std::env::temp_dir().join(format!("ma-direct-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        (DirectEngine::new(&dir, devs, 1 << 26, workers).unwrap(), dir)
    }

    #[test]
    fn striped_roundtrip() {
        let (eng, dir) = mk("rt", 3, 1);
        let data: Vec<u8> = (0..100_000).map(|i| (i % 253) as u8).collect();
        eng.write("w", &data).unwrap();
        let mut out = vec![0u8; data.len()];
        eng.read("w", &mut out).unwrap();
        assert_eq!(out, data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn extents_are_lba_aligned_and_disjoint() {
        let (eng, dir) = mk("al", 2, 1);
        for i in 0..10 {
            eng.write(&format!("t{i}"), &vec![i as u8; 5000 + i * 977]).unwrap();
        }
        let dict = eng.dict.read().unwrap();
        let mut per_dev: HashMap<usize, Vec<(u64, u64)>> = HashMap::new();
        for (ext, _) in dict.values() {
            for e in ext {
                assert_eq!(e.offset % LBA_SIZE as u64, 0, "unaligned extent");
                per_dev.entry(e.dev).or_default().push((
                    e.offset,
                    e.offset + e.len as u64,
                ));
            }
        }
        for (_, mut spans) in per_dev {
            spans.sort_unstable();
            for w in spans.windows(2) {
                assert!(w[0].1 <= w[1].0, "overlapping extents {w:?}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn overwrite_reuses_extents() {
        let (eng, dir) = mk("ow", 2, 1);
        eng.write("t", &[1u8; 40_000]).unwrap();
        let e1 = eng.lookup("t").unwrap().0;
        eng.write("t", &[2u8; 40_000]).unwrap();
        let e2 = eng.lookup("t").unwrap().0;
        assert_eq!(e1, e2, "steady-state overwrite allocates nothing");
        let mut out = vec![0u8; 40_000];
        eng.read("t", &mut out).unwrap();
        assert!(out.iter().all(|&b| b == 2));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn size_change_rejected() {
        let (eng, dir) = mk("sz", 1, 1);
        eng.write("t", &[0u8; 1000]).unwrap();
        assert!(eng.write("t", &[0u8; 2000]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn multiworker_matches_singleworker() {
        let (e1, d1) = mk("w1", 3, 1);
        let (e4, d4) = mk("w4", 3, 4);
        let data: Vec<u8> = (0..300_000).map(|i| (i % 249) as u8).collect();
        e1.write("t", &data).unwrap();
        e4.write("t", &data).unwrap();
        let mut o1 = vec![0u8; data.len()];
        let mut o4 = vec![0u8; data.len()];
        e1.read("t", &mut o1).unwrap();
        e4.read("t", &mut o4).unwrap();
        assert_eq!(o1, data);
        assert_eq!(o4, data);
        std::fs::remove_dir_all(&d1).ok();
        std::fs::remove_dir_all(&d4).ok();
    }

    #[test]
    fn reopen_restores_dictionary_and_data() {
        let dir = std::env::temp_dir()
            .join(format!("ma-direct-reopen-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let data: Vec<u8> = (0..90_000).map(|i| (i % 241) as u8).collect();
        let e1_extents;
        {
            let eng = DirectEngine::new(&dir, 2, 1 << 26, 1).unwrap();
            eng.write("persist/me", &data).unwrap();
            eng.flush("persist/me").unwrap();
            e1_extents = eng.lookup("persist/me").unwrap().0;
        } // engine dropped: simulates process exit
        let eng = DirectEngine::new(&dir, 2, 1 << 26, 1).unwrap();
        assert_eq!(eng.len_of("persist/me"), Some(data.len()));
        assert_eq!(
            eng.lookup("persist/me").unwrap().0,
            e1_extents,
            "extents survive reopen bit-identically"
        );
        let mut out = vec![0u8; data.len()];
        eng.read("persist/me", &mut out).unwrap();
        assert_eq!(out, data);
        // new allocations after reopen must not overlap restored extents
        eng.write("fresh", &[7u8; 30_000]).unwrap();
        let fresh = eng.lookup("fresh").unwrap().0;
        for f in &fresh {
            for e in &e1_extents {
                if f.dev == e.dev {
                    let f_end = f.offset + f.len as u64;
                    let e_end = e.offset + e.len as u64;
                    assert!(
                        f_end <= e.offset || f.offset >= e_end,
                        "fresh extent {f:?} overlaps restored {e:?}"
                    );
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reopen_with_fewer_devices_is_rejected() {
        let dir = std::env::temp_dir()
            .join(format!("ma-direct-shrink-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        {
            let eng = DirectEngine::new(&dir, 3, 1 << 22, 1).unwrap();
            eng.write("t", &[1u8; 50_000]).unwrap();
        }
        let err = DirectEngine::new(&dir, 1, 1 << 22, 1).unwrap_err();
        assert!(err.to_string().contains("references device"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn flush_absent_key_is_noop() {
        let (eng, dir) = mk("fl", 2, 1);
        eng.flush("never/written").unwrap();
        eng.write("t", &[5u8; 10_000]).unwrap();
        eng.flush("t").unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prop_concurrent_tensors_never_overlap() {
        check("direct-alloc", Config { cases: 16, ..Default::default() }, |rng, size| {
            let (eng, dir) = mk(&format!("p{}", rng.next_u64()), 2, 2);
            let keys: Vec<String> = (0..rng.range(2, 10))
                .map(|i| format!("k{i}"))
                .collect();
            std::thread::scope(|s| {
                for (i, k) in keys.iter().enumerate() {
                    let eng = &eng;
                    let n = 1000 + (i * 3779) % (size.max(2) * 64);
                    s.spawn(move || {
                        eng.write(k, &vec![(i % 255) as u8; n]).unwrap();
                    });
                }
            });
            for (i, k) in keys.iter().enumerate() {
                let n = 1000 + (i * 3779) % (size.max(2) * 64);
                let mut out = vec![0u8; n];
                eng.read(k, &mut out).map_err(|e| e.to_string())?;
                prop_assert!(
                    out.iter().all(|&b| b == (i % 255) as u8),
                    "tensor {k} corrupted by concurrent allocation"
                );
            }
            std::fs::remove_dir_all(&dir).ok();
            Ok(())
        });
    }
}
