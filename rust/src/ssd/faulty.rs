//! Fault-injecting engine wrapper — failure-injection testing.
//!
//! Wraps a shared `Arc<dyn NvmeEngine>` and fails a deterministic
//! subset of operations, letting integration tests prove that I/O
//! errors surface as `Err` through the swapper/optimizer/trainer
//! instead of corrupting state or deadlocking the prefetch pipeline —
//! and that the retry layer ([`crate::ssd::retry`]) absorbs transient
//! faults without changing a byte.
//!
//! Three ingredients compose:
//!
//! - **Mode** ([`FaultMode`]): probabilistic (seeded, reproducible
//!   fail rate per op) or transient (every op fails its first N
//!   attempts, then succeeds — the shape bounded retry must absorb;
//!   `N = u32::MAX` is a persistent fault).
//! - **Mask** ([`OpMask`]): which op kinds inject.  *Every* kind is
//!   maskable — including `flush` and `reserve` — so flush-barrier
//!   error paths (`flush_groups`, `Trainer::drain`, the checkpoint
//!   journal's epoch commit) and allocation error paths are
//!   independently exercisable.  The default mask is the data ops
//!   only (read/write/read_at/write_at), which keeps fault tests
//!   aimed at the tile pipeline's data path unless they opt in.
//! - **Metering**: `injected` counts the faults actually thrown,
//!   `delayed` the latency spikes served, `corrupted` the bits
//!   flipped.
//!
//! Two further injections compose orthogonally with the mode, each
//! drawing from its own deterministic op-index stream so enabling one
//! never perturbs another's fault pattern:
//!
//! - **Latency** ([`FaultyEngine::with_latency`]): a seeded subset of
//!   masked ops sleeps a fixed delay plus seeded jitter before
//!   touching the device — the straggler/stall shape the hedged-read
//!   path ([`crate::ssd::HealthTracker`]) must cut short.
//! - **Bit flips** ([`FaultyEngine::with_bit_flips`]): a seeded subset
//!   of masked data ops has one bit flipped — in the returned buffer
//!   for reads (transient misread: a re-read heals), in the bytes
//!   handed down for writes (durable rot: only a rewrite heals) — the
//!   corruption the integrity layer
//!   ([`crate::ssd::IntegrityEngine`]) must detect, every time.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::rng::SplitMix64;

use super::{IoSnapshot, NvmeEngine};

/// Operation kinds the injector can target.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    Read,
    Write,
    ReadAt,
    WriteAt,
    Flush,
    Reserve,
}

impl OpKind {
    fn bit(self) -> u8 {
        match self {
            OpKind::Read => 1 << 0,
            OpKind::Write => 1 << 1,
            OpKind::ReadAt => 1 << 2,
            OpKind::WriteAt => 1 << 3,
            OpKind::Flush => 1 << 4,
            OpKind::Reserve => 1 << 5,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OpKind::Read => "read",
            OpKind::Write => "write",
            OpKind::ReadAt => "ranged-read",
            OpKind::WriteAt => "ranged-write",
            OpKind::Flush => "flush",
            OpKind::Reserve => "reserve",
        }
    }
}

/// Per-op-kind injection mask.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpMask(u8);

impl OpMask {
    /// Data transfers only (read/write/read_at/write_at) — the
    /// historical behavior, and the default.
    pub const DATA: OpMask = OpMask(0b0000_1111);
    /// Every op kind, including `flush` and `reserve`.
    pub const ALL: OpMask = OpMask(0b0011_1111);
    /// No injection at all (useful as a base for `with`).
    pub const NONE: OpMask = OpMask(0);
    /// Flush barriers only.
    pub const FLUSH: OpMask = OpMask(1 << 4);

    pub const fn with(self, kind: OpKind) -> OpMask {
        OpMask(self.0 | kind.bit())
    }

    pub const fn contains(self, kind: OpKind) -> bool {
        self.0 & kind.bit() != 0
    }
}

impl Default for OpMask {
    fn default() -> Self {
        OpMask::DATA
    }
}

enum FaultMode {
    /// Fail each masked op with probability `per_1024/1024`,
    /// deterministic per (seed, op index).
    Random { per_1024: u64, seed: u64 },
    /// Fail the first `fail_first` attempts of each distinct masked op
    /// — keyed by (kind, key, offset) — then succeed.  `u32::MAX`
    /// never recovers (persistent fault).
    Transient { fail_first: u32 },
}

/// Seeded latency-spike injection (see [`FaultyEngine::with_latency`]).
struct Latency {
    per_1024: u64,
    delay: Duration,
    jitter: Duration,
    seed: u64,
}

/// Seeded bit-flip injection (see [`FaultyEngine::with_bit_flips`]).
struct BitFlips {
    per_1024: u64,
    seed: u64,
}

pub struct FaultyEngine {
    inner: Arc<dyn NvmeEngine>,
    mode: FaultMode,
    mask: OpMask,
    op_counter: AtomicU64,
    /// Attempt counts for transient mode, per (kind, key, offset).
    attempts: Mutex<HashMap<(OpKind, String, usize), u32>>,
    latency: Option<Latency>,
    /// Separate op-index stream for latency decisions, so composing
    /// latency with a mode never changes the mode's fault pattern.
    lat_counter: AtomicU64,
    /// Mask override for latency injection (`None` = engine mask).
    lat_mask: Option<OpMask>,
    flips: Option<BitFlips>,
    flip_counter: AtomicU64,
    /// Mask override for bit-flip injection (`None` = engine mask).
    flip_mask: Option<OpMask>,
    pub injected: AtomicU64,
    /// Latency spikes actually served.
    pub delayed: AtomicU64,
    /// Bits actually flipped.
    pub corrupted: AtomicU64,
}

impl FaultyEngine {
    /// Probabilistic injector: each masked op fails with probability
    /// `fail_per_1024 / 1024`, deterministically by `seed` (default
    /// mask: data ops only).
    pub fn new(inner: Arc<dyn NvmeEngine>, fail_per_1024: u64, seed: u64) -> Self {
        Self::build(inner, FaultMode::Random { per_1024: fail_per_1024, seed }, OpMask::DATA)
    }

    fn build(inner: Arc<dyn NvmeEngine>, mode: FaultMode, mask: OpMask) -> Self {
        Self {
            inner,
            mode,
            mask,
            op_counter: AtomicU64::new(0),
            attempts: Mutex::new(HashMap::new()),
            latency: None,
            lat_counter: AtomicU64::new(0),
            lat_mask: None,
            flips: None,
            flip_counter: AtomicU64::new(0),
            flip_mask: None,
            injected: AtomicU64::new(0),
            delayed: AtomicU64::new(0),
            corrupted: AtomicU64::new(0),
        }
    }

    /// Transient injector: each distinct masked op — (kind, key,
    /// offset) — fails its first `fail_first` attempts, then succeeds.
    /// `u32::MAX` models a persistent fault.
    pub fn transient(inner: Arc<dyn NvmeEngine>, fail_first: u32, mask: OpMask) -> Self {
        Self::build(inner, FaultMode::Transient { fail_first }, mask)
    }

    /// Replace the op-kind mask (builder style).
    pub fn with_mask(mut self, mask: OpMask) -> Self {
        self.mask = mask;
        self
    }

    /// Add latency-spike injection: each masked op, with probability
    /// `per_1024 / 1024` (deterministic by `seed`), sleeps `delay`
    /// plus a seeded jitter uniform in `[0, jitter)` before reaching
    /// the device.  `per_1024 = 1024` stalls every masked op; a large
    /// `delay` models a hung submission.  Composes with either fault
    /// mode without changing its pattern.
    pub fn with_latency(
        mut self,
        per_1024: u64,
        delay: Duration,
        jitter: Duration,
        seed: u64,
    ) -> Self {
        self.latency = Some(Latency { per_1024, delay, jitter, seed });
        self
    }

    /// Add bit-flip corruption: each masked *data* op, with
    /// probability `per_1024 / 1024` (deterministic by `seed`), has
    /// one seeded-position bit flipped — in the out buffer for reads
    /// (transient: re-read heals), in the written bytes for writes
    /// (durable: re-read keeps failing).  Composes with either fault
    /// mode without changing its pattern.
    pub fn with_bit_flips(mut self, per_1024: u64, seed: u64) -> Self {
        self.flips = Some(BitFlips { per_1024, seed });
        self
    }

    /// Gate latency injection by its own mask instead of the engine
    /// mask — lets spikes target ops the error mode spares.
    pub fn with_latency_mask(mut self, mask: OpMask) -> Self {
        self.lat_mask = Some(mask);
        self
    }

    /// Gate bit-flip injection by its own mask instead of the engine
    /// mask — lets corruption target ops the error mode spares.
    pub fn with_flip_mask(mut self, mask: OpMask) -> Self {
        self.flip_mask = Some(mask);
        self
    }

    fn should_fail(&self, kind: OpKind, key: &str, offset: usize) -> bool {
        if !self.mask.contains(kind) {
            return false;
        }
        let fail = match &self.mode {
            FaultMode::Random { per_1024, seed } => {
                let op = self.op_counter.fetch_add(1, Ordering::Relaxed);
                // deterministic per (seed, op index): reproducible
                let mut rng = SplitMix64::new(seed ^ op.wrapping_mul(0x9E37_79B9));
                rng.next_u64() % 1024 < *per_1024
            }
            FaultMode::Transient { fail_first } => {
                let mut at = self.attempts.lock().unwrap();
                let n = at.entry((kind, key.to_string(), offset)).or_insert(0);
                *n = n.saturating_add(1);
                *n <= *fail_first
            }
        };
        if fail {
            self.injected.fetch_add(1, Ordering::Relaxed);
        }
        fail
    }

    fn inject(&self, kind: OpKind, key: &str, offset: usize) -> anyhow::Result<()> {
        if self.should_fail(kind, key, offset) {
            anyhow::bail!("injected {} fault on '{key}'", kind.name());
        }
        Ok(())
    }

    /// Serve a latency spike for this op if the seeded draw says so.
    fn maybe_delay(&self, kind: OpKind) {
        let Some(lat) = &self.latency else { return };
        if !self.lat_mask.unwrap_or(self.mask).contains(kind) {
            return;
        }
        let op = self.lat_counter.fetch_add(1, Ordering::Relaxed);
        let mut rng =
            SplitMix64::new(lat.seed ^ op.wrapping_mul(0x9E37_79B9) ^ 0x5105_5105);
        if rng.next_u64() % 1024 < lat.per_1024 {
            let jitter_ns = lat.jitter.as_nanos() as u64;
            let jitter = if jitter_ns == 0 { 0 } else { rng.next_u64() % jitter_ns };
            self.delayed.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(lat.delay + Duration::from_nanos(jitter));
        }
    }

    /// Seeded bit position to flip for this data op, if any.
    fn flip_bit(&self, kind: OpKind, len: usize) -> Option<usize> {
        let fl = self.flips.as_ref()?;
        if !self.flip_mask.unwrap_or(self.mask).contains(kind) || len == 0 {
            return None;
        }
        let op = self.flip_counter.fetch_add(1, Ordering::Relaxed);
        let mut rng =
            SplitMix64::new(fl.seed ^ op.wrapping_mul(0x9E37_79B9) ^ 0xF11B_F11B);
        if rng.next_u64() % 1024 < fl.per_1024 {
            self.corrupted.fetch_add(1, Ordering::Relaxed);
            Some((rng.next_u64() % (len as u64 * 8)) as usize)
        } else {
            None
        }
    }

    fn maybe_flip_out(&self, kind: OpKind, out: &mut [u8]) {
        if let Some(bit) = self.flip_bit(kind, out.len()) {
            out[bit / 8] ^= 1 << (bit % 8);
        }
    }

    /// Corrupted copy of `data` for the write path, if this op flips.
    fn maybe_flip_copy(&self, kind: OpKind, data: &[u8]) -> Option<Vec<u8>> {
        self.flip_bit(kind, data.len()).map(|bit| {
            let mut v = data.to_vec();
            v[bit / 8] ^= 1 << (bit % 8);
            v
        })
    }
}

impl NvmeEngine for FaultyEngine {
    fn write(&self, key: &str, data: &[u8]) -> anyhow::Result<()> {
        self.maybe_delay(OpKind::Write);
        self.inject(OpKind::Write, key, 0)?;
        match self.maybe_flip_copy(OpKind::Write, data) {
            Some(corrupt) => self.inner.write(key, &corrupt),
            None => self.inner.write(key, data),
        }
    }

    fn read(&self, key: &str, out: &mut [u8]) -> anyhow::Result<()> {
        self.maybe_delay(OpKind::Read);
        self.inject(OpKind::Read, key, 0)?;
        self.inner.read(key, out)?;
        self.maybe_flip_out(OpKind::Read, out);
        Ok(())
    }

    fn read_at(&self, key: &str, offset: usize, out: &mut [u8]) -> anyhow::Result<()> {
        self.maybe_delay(OpKind::ReadAt);
        self.inject(OpKind::ReadAt, key, offset)?;
        self.inner.read_at(key, offset, out)?;
        self.maybe_flip_out(OpKind::ReadAt, out);
        Ok(())
    }

    fn write_at(&self, key: &str, offset: usize, data: &[u8]) -> anyhow::Result<()> {
        self.maybe_delay(OpKind::WriteAt);
        self.inject(OpKind::WriteAt, key, offset)?;
        match self.maybe_flip_copy(OpKind::WriteAt, data) {
            Some(corrupt) => self.inner.write_at(key, offset, &corrupt),
            None => self.inner.write_at(key, offset, data),
        }
    }

    fn reserve(&self, key: &str, len: usize) -> anyhow::Result<()> {
        self.maybe_delay(OpKind::Reserve);
        self.inject(OpKind::Reserve, key, 0)?;
        self.inner.reserve(key, len)
    }

    fn flush(&self, key: &str) -> anyhow::Result<()> {
        self.maybe_delay(OpKind::Flush);
        self.inject(OpKind::Flush, key, 0)?;
        self.inner.flush(key)
    }

    fn len_of(&self, key: &str) -> Option<usize> {
        self.inner.len_of(key)
    }

    fn stats(&self) -> IoSnapshot {
        self.inner.stats()
    }

    fn label(&self) -> &'static str {
        "faulty"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssd::DirectEngine;

    fn direct(tag: &str) -> (Arc<dyn NvmeEngine>, std::path::PathBuf) {
        let dir = std::env::temp_dir()
            .join(format!("ma-faulty-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let e: Arc<dyn NvmeEngine> =
            Arc::new(DirectEngine::new(&dir, 1, 1 << 22, 1).unwrap());
        (e, dir)
    }

    fn mk(fail: u64) -> (FaultyEngine, std::path::PathBuf) {
        let (inner, dir) = direct(&format!("p{fail}"));
        (FaultyEngine::new(inner, fail, 7), dir)
    }

    #[test]
    fn zero_rate_never_fails() {
        let (eng, dir) = mk(0);
        for i in 0..50 {
            eng.write(&format!("k{i}"), &[1u8; 128]).unwrap();
        }
        assert_eq!(eng.injected.load(Ordering::Relaxed), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn faults_are_deterministic_and_surface_as_errors() {
        let (eng, dir) = mk(512); // ~50%
        let results: Vec<bool> = (0..100)
            .map(|i| eng.write(&format!("k{i}"), &[0u8; 64]).is_ok())
            .collect();
        let fails = results.iter().filter(|ok| !**ok).count();
        assert!((20..80).contains(&fails), "{fails} fails");
        // same seed -> same pattern
        let (eng2, dir2) = mk(512);
        let results2: Vec<bool> = (0..100)
            .map(|i| eng2.write(&format!("k{i}"), &[0u8; 64]).is_ok())
            .collect();
        assert_eq!(results, results2);
        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&dir2).ok();
    }

    #[test]
    fn successful_ops_still_roundtrip() {
        let (eng, dir) = mk(300);
        let mut stored = Vec::new();
        for i in 0..50 {
            let data = vec![i as u8; 256];
            if eng.write(&format!("k{i}"), &data).is_ok() {
                stored.push((format!("k{i}"), data));
            }
        }
        for (k, want) in stored {
            let mut out = vec![0u8; want.len()];
            if eng.read(&k, &mut out).is_ok() {
                assert_eq!(out, want);
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn default_mask_spares_flush_and_reserve() {
        let (inner, dir) = direct("mask-def");
        let eng = FaultyEngine::new(inner, 1024, 3); // fail every data op
        assert!(eng.write("k", &[1u8; 64]).is_err());
        eng.reserve("r", 4096).unwrap();
        eng.flush("r").unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn flush_mask_injects_only_flush() {
        let (inner, dir) = direct("mask-fl");
        let eng = FaultyEngine::new(inner, 1024, 3).with_mask(OpMask::FLUSH);
        eng.write("k", &[1u8; 64]).unwrap();
        let err = eng.flush("k").unwrap_err();
        assert!(err.to_string().contains("flush"), "{err}");
        assert!(eng.injected.load(Ordering::Relaxed) > 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn transient_mode_fails_then_recovers_per_op() {
        let (inner, dir) = direct("tr");
        let eng = FaultyEngine::transient(inner, 2, OpMask::ALL);
        // distinct (kind, key, offset) ops each get their own counter
        assert!(eng.write("a", &[1u8; 32]).is_err());
        assert!(eng.write("b", &[2u8; 32]).is_err());
        assert!(eng.write("a", &[1u8; 32]).is_err());
        eng.write("a", &[1u8; 32]).unwrap(); // third attempt succeeds
        assert!(eng.write("b", &[2u8; 32]).is_err());
        eng.write("b", &[2u8; 32]).unwrap();
        // ranged ops key by offset: two tiles fail independently
        eng.reserve("t", 8192).unwrap_err();
        eng.reserve("t", 8192).unwrap_err();
        eng.reserve("t", 8192).unwrap();
        for off in [0usize, 4096] {
            assert!(eng.write_at("t", off, &[3u8; 64]).is_err());
            assert!(eng.write_at("t", off, &[3u8; 64]).is_err());
            eng.write_at("t", off, &[3u8; 64]).unwrap();
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn persistent_transient_never_recovers() {
        let (inner, dir) = direct("pers");
        let eng = FaultyEngine::transient(inner, u32::MAX, OpMask::ALL);
        for _ in 0..20 {
            assert!(eng.write("k", &[0u8; 16]).is_err());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn latency_spikes_are_masked_metered_and_deterministic() {
        let (inner, dir) = direct("lat");
        // every data op spikes 5 ms; flush/reserve spared by the mask
        let eng = FaultyEngine::new(inner, 0, 1).with_latency(
            1024,
            Duration::from_millis(5),
            Duration::ZERO,
            9,
        );
        let t0 = std::time::Instant::now();
        for i in 0..3 {
            eng.write(&format!("k{i}"), &[0u8; 64]).unwrap();
        }
        assert!(t0.elapsed() >= Duration::from_millis(15), "spikes not served");
        assert_eq!(eng.delayed.load(Ordering::Relaxed), 3);
        let t1 = std::time::Instant::now();
        eng.flush("k0").unwrap();
        assert!(t1.elapsed() < Duration::from_millis(5), "mask ignored");
        assert_eq!(eng.delayed.load(Ordering::Relaxed), 3);
        // probabilistic spikes reproduce per seed
        let (i2, dir2) = direct("lat2");
        let mk = |inner: Arc<dyn NvmeEngine>| {
            FaultyEngine::new(inner, 0, 1).with_latency(
                512,
                Duration::from_micros(10),
                Duration::from_micros(10),
                77,
            )
        };
        let a = mk(i2.clone());
        let b = mk(i2);
        for i in 0..40 {
            a.write(&format!("a{i}"), &[0u8; 8]).unwrap();
            b.write(&format!("b{i}"), &[0u8; 8]).unwrap();
        }
        assert_eq!(
            a.delayed.load(Ordering::Relaxed),
            b.delayed.load(Ordering::Relaxed)
        );
        assert!(a.delayed.load(Ordering::Relaxed) > 0);
        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&dir2).ok();
    }

    #[test]
    fn latency_composes_with_transient_mode_without_changing_its_pattern() {
        let (inner, dir) = direct("lat-tr");
        let eng = FaultyEngine::transient(inner, 2, OpMask::DATA).with_latency(
            1024,
            Duration::from_millis(1),
            Duration::ZERO,
            5,
        );
        // the transient fail-twice-then-succeed shape is untouched,
        // and the spikes fire on faulted and clean attempts alike
        assert!(eng.write("a", &[1u8; 32]).is_err());
        assert!(eng.write("a", &[1u8; 32]).is_err());
        eng.write("a", &[1u8; 32]).unwrap();
        assert_eq!(eng.injected.load(Ordering::Relaxed), 2);
        assert_eq!(eng.delayed.load(Ordering::Relaxed), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn read_side_flips_are_transient_write_side_flips_are_durable() {
        let (inner, dir) = direct("flip");
        let want = vec![0xA5u8; 1024];
        inner.write("clean", &want).unwrap();
        let eng = FaultyEngine::new(inner.clone(), 0, 1).with_bit_flips(1024, 13);
        // read-side: the out buffer corrupts, the durable bytes don't
        let mut out = vec![0u8; want.len()];
        eng.read("clean", &mut out).unwrap();
        let diff: u32 =
            out.iter().zip(&want).map(|(a, b)| (a ^ b).count_ones()).sum();
        assert_eq!(diff, 1, "exactly one bit flips per corrupted op");
        let mut out2 = vec![0u8; want.len()];
        inner.read("clean", &mut out2).unwrap();
        assert_eq!(out2, want, "durable bytes must be untouched by read flips");
        // write-side: the durable bytes corrupt by exactly one bit
        eng.write("rot", &want).unwrap();
        let mut rot = vec![0u8; want.len()];
        inner.read("rot", &mut rot).unwrap();
        let diff: u32 =
            rot.iter().zip(&want).map(|(a, b)| (a ^ b).count_ones()).sum();
        assert_eq!(diff, 1);
        assert_eq!(eng.corrupted.load(Ordering::Relaxed), 2);
        // same seed, same positions
        let eng2 = FaultyEngine::new(inner.clone(), 0, 1).with_bit_flips(1024, 13);
        let mut out3 = vec![0u8; want.len()];
        eng2.read("clean", &mut out3).unwrap();
        assert_eq!(out3, out);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bit_flips_compose_with_persistent_mode() {
        let (inner, dir) = direct("flip-pers");
        inner.write("k", &[7u8; 256]).unwrap();
        // persistent write faults + read-side corruption coexist:
        // writes always error, reads succeed but corrupt
        let eng = FaultyEngine::transient(
            inner,
            u32::MAX,
            OpMask::NONE.with(OpKind::Write).with(OpKind::WriteAt),
        )
        .with_bit_flips(1024, 3)
        .with_flip_mask(OpMask::NONE.with(OpKind::Read).with(OpKind::ReadAt));
        let mut out = vec![0u8; 256];
        eng.read("k", &mut out).unwrap();
        assert_ne!(out, vec![7u8; 256], "read must corrupt");
        assert!(eng.write("k", &[7u8; 256]).is_err(), "write must keep failing");
        std::fs::remove_dir_all(&dir).ok();
    }
}
