//! Fault-injecting engine wrapper — failure-injection testing.
//!
//! Wraps a shared `Arc<dyn NvmeEngine>` and fails a deterministic
//! subset of operations, letting integration tests prove that I/O
//! errors surface as `Err` through the swapper/optimizer/trainer
//! instead of corrupting state or deadlocking the prefetch pipeline —
//! and that the retry layer ([`crate::ssd::retry`]) absorbs transient
//! faults without changing a byte.
//!
//! Three ingredients compose:
//!
//! - **Mode** ([`FaultMode`]): probabilistic (seeded, reproducible
//!   fail rate per op) or transient (every op fails its first N
//!   attempts, then succeeds — the shape bounded retry must absorb;
//!   `N = u32::MAX` is a persistent fault).
//! - **Mask** ([`OpMask`]): which op kinds inject.  *Every* kind is
//!   maskable — including `flush` and `reserve` — so flush-barrier
//!   error paths (`flush_groups`, `Trainer::drain`, the checkpoint
//!   journal's epoch commit) and allocation error paths are
//!   independently exercisable.  The default mask is the data ops
//!   only (read/write/read_at/write_at), which keeps fault tests
//!   aimed at the tile pipeline's data path unless they opt in.
//! - **Metering**: `injected` counts the faults actually thrown.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::rng::SplitMix64;

use super::{IoSnapshot, NvmeEngine};

/// Operation kinds the injector can target.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    Read,
    Write,
    ReadAt,
    WriteAt,
    Flush,
    Reserve,
}

impl OpKind {
    fn bit(self) -> u8 {
        match self {
            OpKind::Read => 1 << 0,
            OpKind::Write => 1 << 1,
            OpKind::ReadAt => 1 << 2,
            OpKind::WriteAt => 1 << 3,
            OpKind::Flush => 1 << 4,
            OpKind::Reserve => 1 << 5,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OpKind::Read => "read",
            OpKind::Write => "write",
            OpKind::ReadAt => "ranged-read",
            OpKind::WriteAt => "ranged-write",
            OpKind::Flush => "flush",
            OpKind::Reserve => "reserve",
        }
    }
}

/// Per-op-kind injection mask.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpMask(u8);

impl OpMask {
    /// Data transfers only (read/write/read_at/write_at) — the
    /// historical behavior, and the default.
    pub const DATA: OpMask = OpMask(0b0000_1111);
    /// Every op kind, including `flush` and `reserve`.
    pub const ALL: OpMask = OpMask(0b0011_1111);
    /// No injection at all (useful as a base for `with`).
    pub const NONE: OpMask = OpMask(0);
    /// Flush barriers only.
    pub const FLUSH: OpMask = OpMask(1 << 4);

    pub const fn with(self, kind: OpKind) -> OpMask {
        OpMask(self.0 | kind.bit())
    }

    pub const fn contains(self, kind: OpKind) -> bool {
        self.0 & kind.bit() != 0
    }
}

impl Default for OpMask {
    fn default() -> Self {
        OpMask::DATA
    }
}

enum FaultMode {
    /// Fail each masked op with probability `per_1024/1024`,
    /// deterministic per (seed, op index).
    Random { per_1024: u64, seed: u64 },
    /// Fail the first `fail_first` attempts of each distinct masked op
    /// — keyed by (kind, key, offset) — then succeed.  `u32::MAX`
    /// never recovers (persistent fault).
    Transient { fail_first: u32 },
}

pub struct FaultyEngine {
    inner: Arc<dyn NvmeEngine>,
    mode: FaultMode,
    mask: OpMask,
    op_counter: AtomicU64,
    /// Attempt counts for transient mode, per (kind, key, offset).
    attempts: Mutex<HashMap<(OpKind, String, usize), u32>>,
    pub injected: AtomicU64,
}

impl FaultyEngine {
    /// Probabilistic injector: each masked op fails with probability
    /// `fail_per_1024 / 1024`, deterministically by `seed` (default
    /// mask: data ops only).
    pub fn new(inner: Arc<dyn NvmeEngine>, fail_per_1024: u64, seed: u64) -> Self {
        Self {
            inner,
            mode: FaultMode::Random { per_1024: fail_per_1024, seed },
            mask: OpMask::DATA,
            op_counter: AtomicU64::new(0),
            attempts: Mutex::new(HashMap::new()),
            injected: AtomicU64::new(0),
        }
    }

    /// Transient injector: each distinct masked op — (kind, key,
    /// offset) — fails its first `fail_first` attempts, then succeeds.
    /// `u32::MAX` models a persistent fault.
    pub fn transient(inner: Arc<dyn NvmeEngine>, fail_first: u32, mask: OpMask) -> Self {
        Self {
            inner,
            mode: FaultMode::Transient { fail_first },
            mask,
            op_counter: AtomicU64::new(0),
            attempts: Mutex::new(HashMap::new()),
            injected: AtomicU64::new(0),
        }
    }

    /// Replace the op-kind mask (builder style).
    pub fn with_mask(mut self, mask: OpMask) -> Self {
        self.mask = mask;
        self
    }

    fn should_fail(&self, kind: OpKind, key: &str, offset: usize) -> bool {
        if !self.mask.contains(kind) {
            return false;
        }
        let fail = match &self.mode {
            FaultMode::Random { per_1024, seed } => {
                let op = self.op_counter.fetch_add(1, Ordering::Relaxed);
                // deterministic per (seed, op index): reproducible
                let mut rng = SplitMix64::new(seed ^ op.wrapping_mul(0x9E37_79B9));
                rng.next_u64() % 1024 < *per_1024
            }
            FaultMode::Transient { fail_first } => {
                let mut at = self.attempts.lock().unwrap();
                let n = at.entry((kind, key.to_string(), offset)).or_insert(0);
                *n = n.saturating_add(1);
                *n <= *fail_first
            }
        };
        if fail {
            self.injected.fetch_add(1, Ordering::Relaxed);
        }
        fail
    }

    fn inject(&self, kind: OpKind, key: &str, offset: usize) -> anyhow::Result<()> {
        if self.should_fail(kind, key, offset) {
            anyhow::bail!("injected {} fault on '{key}'", kind.name());
        }
        Ok(())
    }
}

impl NvmeEngine for FaultyEngine {
    fn write(&self, key: &str, data: &[u8]) -> anyhow::Result<()> {
        self.inject(OpKind::Write, key, 0)?;
        self.inner.write(key, data)
    }

    fn read(&self, key: &str, out: &mut [u8]) -> anyhow::Result<()> {
        self.inject(OpKind::Read, key, 0)?;
        self.inner.read(key, out)
    }

    fn read_at(&self, key: &str, offset: usize, out: &mut [u8]) -> anyhow::Result<()> {
        self.inject(OpKind::ReadAt, key, offset)?;
        self.inner.read_at(key, offset, out)
    }

    fn write_at(&self, key: &str, offset: usize, data: &[u8]) -> anyhow::Result<()> {
        self.inject(OpKind::WriteAt, key, offset)?;
        self.inner.write_at(key, offset, data)
    }

    fn reserve(&self, key: &str, len: usize) -> anyhow::Result<()> {
        self.inject(OpKind::Reserve, key, 0)?;
        self.inner.reserve(key, len)
    }

    fn flush(&self, key: &str) -> anyhow::Result<()> {
        self.inject(OpKind::Flush, key, 0)?;
        self.inner.flush(key)
    }

    fn len_of(&self, key: &str) -> Option<usize> {
        self.inner.len_of(key)
    }

    fn stats(&self) -> IoSnapshot {
        self.inner.stats()
    }

    fn label(&self) -> &'static str {
        "faulty"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssd::DirectEngine;

    fn direct(tag: &str) -> (Arc<dyn NvmeEngine>, std::path::PathBuf) {
        let dir = std::env::temp_dir()
            .join(format!("ma-faulty-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let e: Arc<dyn NvmeEngine> =
            Arc::new(DirectEngine::new(&dir, 1, 1 << 22, 1).unwrap());
        (e, dir)
    }

    fn mk(fail: u64) -> (FaultyEngine, std::path::PathBuf) {
        let (inner, dir) = direct(&format!("p{fail}"));
        (FaultyEngine::new(inner, fail, 7), dir)
    }

    #[test]
    fn zero_rate_never_fails() {
        let (eng, dir) = mk(0);
        for i in 0..50 {
            eng.write(&format!("k{i}"), &[1u8; 128]).unwrap();
        }
        assert_eq!(eng.injected.load(Ordering::Relaxed), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn faults_are_deterministic_and_surface_as_errors() {
        let (eng, dir) = mk(512); // ~50%
        let results: Vec<bool> = (0..100)
            .map(|i| eng.write(&format!("k{i}"), &[0u8; 64]).is_ok())
            .collect();
        let fails = results.iter().filter(|ok| !**ok).count();
        assert!((20..80).contains(&fails), "{fails} fails");
        // same seed -> same pattern
        let (eng2, dir2) = mk(512);
        let results2: Vec<bool> = (0..100)
            .map(|i| eng2.write(&format!("k{i}"), &[0u8; 64]).is_ok())
            .collect();
        assert_eq!(results, results2);
        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&dir2).ok();
    }

    #[test]
    fn successful_ops_still_roundtrip() {
        let (eng, dir) = mk(300);
        let mut stored = Vec::new();
        for i in 0..50 {
            let data = vec![i as u8; 256];
            if eng.write(&format!("k{i}"), &data).is_ok() {
                stored.push((format!("k{i}"), data));
            }
        }
        for (k, want) in stored {
            let mut out = vec![0u8; want.len()];
            if eng.read(&k, &mut out).is_ok() {
                assert_eq!(out, want);
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn default_mask_spares_flush_and_reserve() {
        let (inner, dir) = direct("mask-def");
        let eng = FaultyEngine::new(inner, 1024, 3); // fail every data op
        assert!(eng.write("k", &[1u8; 64]).is_err());
        eng.reserve("r", 4096).unwrap();
        eng.flush("r").unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn flush_mask_injects_only_flush() {
        let (inner, dir) = direct("mask-fl");
        let eng = FaultyEngine::new(inner, 1024, 3).with_mask(OpMask::FLUSH);
        eng.write("k", &[1u8; 64]).unwrap();
        let err = eng.flush("k").unwrap_err();
        assert!(err.to_string().contains("flush"), "{err}");
        assert!(eng.injected.load(Ordering::Relaxed) > 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn transient_mode_fails_then_recovers_per_op() {
        let (inner, dir) = direct("tr");
        let eng = FaultyEngine::transient(inner, 2, OpMask::ALL);
        // distinct (kind, key, offset) ops each get their own counter
        assert!(eng.write("a", &[1u8; 32]).is_err());
        assert!(eng.write("b", &[2u8; 32]).is_err());
        assert!(eng.write("a", &[1u8; 32]).is_err());
        eng.write("a", &[1u8; 32]).unwrap(); // third attempt succeeds
        assert!(eng.write("b", &[2u8; 32]).is_err());
        eng.write("b", &[2u8; 32]).unwrap();
        // ranged ops key by offset: two tiles fail independently
        eng.reserve("t", 8192).unwrap_err();
        eng.reserve("t", 8192).unwrap_err();
        eng.reserve("t", 8192).unwrap();
        for off in [0usize, 4096] {
            assert!(eng.write_at("t", off, &[3u8; 64]).is_err());
            assert!(eng.write_at("t", off, &[3u8; 64]).is_err());
            eng.write_at("t", off, &[3u8; 64]).unwrap();
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn persistent_transient_never_recovers() {
        let (inner, dir) = direct("pers");
        let eng = FaultyEngine::transient(inner, u32::MAX, OpMask::ALL);
        for _ in 0..20 {
            assert!(eng.write("k", &[0u8; 16]).is_err());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
