//! Fault-injecting engine wrapper — failure-injection testing.
//!
//! Wraps any `NvmeEngine` and fails a deterministic subset of
//! operations (seeded), letting integration tests prove that I/O
//! errors surface as `Err` through the swapper/optimizer/trainer
//! instead of corrupting state or deadlocking the prefetch pipeline.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::rng::SplitMix64;

use super::{IoSnapshot, NvmeEngine};

pub struct FaultyEngine<E> {
    inner: E,
    /// Probability of failing each op, in 1/1024 units.
    fail_per_1024: u64,
    seed: u64,
    op_counter: AtomicU64,
    pub injected: AtomicU64,
}

impl<E: NvmeEngine> FaultyEngine<E> {
    pub fn new(inner: E, fail_per_1024: u64, seed: u64) -> Self {
        Self {
            inner,
            fail_per_1024,
            seed,
            op_counter: AtomicU64::new(0),
            injected: AtomicU64::new(0),
        }
    }

    fn should_fail(&self) -> bool {
        let op = self.op_counter.fetch_add(1, Ordering::Relaxed);
        // deterministic per (seed, op index): reproducible failures
        let mut rng = SplitMix64::new(self.seed ^ op.wrapping_mul(0x9E37_79B9));
        let fail = rng.next_u64() % 1024 < self.fail_per_1024;
        if fail {
            self.injected.fetch_add(1, Ordering::Relaxed);
        }
        fail
    }
}

impl<E: NvmeEngine> NvmeEngine for FaultyEngine<E> {
    fn write(&self, key: &str, data: &[u8]) -> anyhow::Result<()> {
        if self.should_fail() {
            anyhow::bail!("injected write fault on '{key}'");
        }
        self.inner.write(key, data)
    }

    fn read(&self, key: &str, out: &mut [u8]) -> anyhow::Result<()> {
        if self.should_fail() {
            anyhow::bail!("injected read fault on '{key}'");
        }
        self.inner.read(key, out)
    }

    fn read_at(&self, key: &str, offset: usize, out: &mut [u8]) -> anyhow::Result<()> {
        if self.should_fail() {
            anyhow::bail!("injected ranged-read fault on '{key}'");
        }
        self.inner.read_at(key, offset, out)
    }

    fn write_at(&self, key: &str, offset: usize, data: &[u8]) -> anyhow::Result<()> {
        if self.should_fail() {
            anyhow::bail!("injected ranged-write fault on '{key}'");
        }
        self.inner.write_at(key, offset, data)
    }

    fn reserve(&self, key: &str, len: usize) -> anyhow::Result<()> {
        // allocation, not a data transfer: forwarded without injection
        // so fault tests target the tile pipeline's data path
        self.inner.reserve(key, len)
    }

    fn flush(&self, key: &str) -> anyhow::Result<()> {
        self.inner.flush(key)
    }

    fn len_of(&self, key: &str) -> Option<usize> {
        self.inner.len_of(key)
    }

    fn stats(&self) -> IoSnapshot {
        self.inner.stats()
    }

    fn label(&self) -> &'static str {
        "faulty"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssd::DirectEngine;

    fn mk(fail: u64) -> (FaultyEngine<DirectEngine>, std::path::PathBuf) {
        let dir = std::env::temp_dir()
            .join(format!("ma-faulty-{fail}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let inner = DirectEngine::new(&dir, 1, 1 << 22, 1).unwrap();
        (FaultyEngine::new(inner, fail, 7), dir)
    }

    #[test]
    fn zero_rate_never_fails() {
        let (eng, dir) = mk(0);
        for i in 0..50 {
            eng.write(&format!("k{i}"), &[1u8; 128]).unwrap();
        }
        assert_eq!(eng.injected.load(Ordering::Relaxed), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn faults_are_deterministic_and_surface_as_errors() {
        let (eng, dir) = mk(512); // ~50%
        let results: Vec<bool> = (0..100)
            .map(|i| eng.write(&format!("k{i}"), &[0u8; 64]).is_ok())
            .collect();
        let fails = results.iter().filter(|ok| !**ok).count();
        assert!((20..80).contains(&fails), "{fails} fails");
        // same seed -> same pattern
        let (eng2, dir2) = mk(512);
        let results2: Vec<bool> = (0..100)
            .map(|i| eng2.write(&format!("k{i}"), &[0u8; 64]).is_ok())
            .collect();
        assert_eq!(results, results2);
        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&dir2).ok();
    }

    #[test]
    fn successful_ops_still_roundtrip() {
        let (eng, dir) = mk(300);
        let mut stored = Vec::new();
        for i in 0..50 {
            let data = vec![i as u8; 256];
            if eng.write(&format!("k{i}"), &data).is_ok() {
                stored.push((format!("k{i}"), data));
            }
        }
        for (k, want) in stored {
            let mut out = vec![0u8; want.len()];
            if eng.read(&k, &mut out).is_ok() {
                assert_eq!(out, want);
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
