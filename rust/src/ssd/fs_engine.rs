//! Filesystem-based SSD engine — the DeepNVMe/ZeRO-Infinity baseline.
//!
//! Each tensor is a separate file (paper §III-D: "each tensor is
//! offloaded to a separate file, allowing file systems such as ext4 to
//! manage storage allocation").  Multiple devices are emulated as
//! directory roots joined in software RAID0: the tensor's bytes are
//! striped across per-device segment files at `stripe` granularity,
//! which is exactly what md-RAID0 + one-file-per-tensor does at block
//! level.  Every call pays the filesystem taxes the paper measures:
//! path resolution, open/create, metadata updates, and fsync-backed
//! allocation-table writes.
//!
//! Like md-RAID0 — which issues member bios concurrently — each
//! emulated device owns a persistent single-worker queue and a
//! transfer fans its per-member chunk lists across them, so the
//! baseline is not handicapped below its real-world counterpart.  The
//! §III-D taxes (open/create, journal fsync, length metadata) stay
//! strictly serial, as ext4 keeps them.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::os::unix::fs::FileExt;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::queue::{io_scope, IoExecutor};
use super::{IoSnapshot, IoStats, NvmeEngine};

pub struct FsEngine {
    devices: Vec<PathBuf>,
    /// One persistent member queue per device (md-RAID0 concurrency).
    queues: Vec<IoExecutor>,
    stripe: usize,
    stats: IoStats,
    /// Directory metadata mutex: ext4 serializes directory updates; the
    /// journal file emulates its metadata/allocation writes.
    meta: Mutex<()>,
    /// Optional member-fd cache (§III-D ablation): skips the per-call
    /// open/create — the *path-resolution* tax — while the journal and
    /// sync taxes stay.  `None` = the faithful baseline.
    fd_cache: Option<Mutex<FdCache>>,
}

/// LRU-stamped fd cache: bounded so a paper-scale tensor population
/// cannot exhaust the process fd limit, with least-recently-used
/// eviction so a working set larger than the cap degrades gracefully
/// instead of thrashing hot fds.
#[derive(Default)]
struct FdCache {
    files: HashMap<PathBuf, (Arc<File>, u64)>,
    clock: u64,
}

impl FsEngine {
    /// `root/devN/` stands in for each ext4-formatted SSD. `stripe` is
    /// the RAID0 chunk size (md default 512 KiB).
    pub fn new(root: &std::path::Path, devices: usize, stripe: usize) -> anyhow::Result<Self> {
        Self::with_fd_cache(root, devices, stripe, false)
    }

    /// [`Self::new`], optionally caching member fds so the §III-D
    /// ablation can separate the path-resolution tax from the journal
    /// tax (`TrainSpec::fs_cached_fds`).
    pub fn with_fd_cache(
        root: &std::path::Path,
        devices: usize,
        stripe: usize,
        cached_fds: bool,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(devices >= 1 && stripe >= 4096);
        let devs: Vec<PathBuf> = (0..devices).map(|i| root.join(format!("dev{i}"))).collect();
        for d in &devs {
            std::fs::create_dir_all(d)?;
        }
        let queues = (0..devices).map(|_| IoExecutor::new(1)).collect();
        Ok(Self {
            devices: devs,
            queues,
            stripe,
            stats: IoStats::default(),
            meta: Mutex::new(()),
            fd_cache: cached_fds.then(|| Mutex::new(FdCache::default())),
        })
    }

    fn seg_path(&self, key: &str, dev: usize) -> PathBuf {
        // one file per tensor per device (its RAID0 member extent)
        self.devices[dev].join(format!("{}.seg", sanitize(key)))
    }

    /// Bound on cached member fds (eviction is safe mid-transfer:
    /// in-flight users hold their own `Arc`).
    const FD_CACHE_CAP: usize = 512;

    /// Open a member file for writing — through the fd cache when
    /// enabled (cached fds are opened read+write so one handle serves
    /// both directions).
    fn open_rw(&self, key: &str, dev: usize) -> anyhow::Result<Arc<File>> {
        let path = self.seg_path(key, dev);
        if let Some(cache) = &self.fd_cache {
            let mut c = cache.lock().unwrap();
            c.clock += 1;
            let now = c.clock;
            if let Some((f, stamp)) = c.files.get_mut(&path) {
                *stamp = now;
                return Ok(Arc::clone(f));
            }
            let f = Arc::new(
                OpenOptions::new()
                    .create(true)
                    .read(true)
                    .write(true)
                    .truncate(false)
                    .open(&path)?,
            );
            if c.files.len() >= Self::FD_CACHE_CAP {
                if let Some(victim) = c
                    .files
                    .iter()
                    .min_by_key(|(_, (_, stamp))| *stamp)
                    .map(|(p, _)| p.clone())
                {
                    c.files.remove(&victim);
                }
            }
            c.files.insert(path, (Arc::clone(&f), now));
            return Ok(f);
        }
        Ok(Arc::new(
            OpenOptions::new()
                .create(true)
                .write(true)
                .truncate(false)
                .open(path)?,
        ))
    }

    /// Open a member file for reading.  Serves from the fd cache when
    /// the write path already populated it; a miss falls back to a
    /// plain read-only open (uncached) so read failure semantics —
    /// missing files error, nothing is created — match the baseline.
    fn open_ro(&self, key: &str, dev: usize) -> anyhow::Result<Arc<File>> {
        let path = self.seg_path(key, dev);
        if let Some(cache) = &self.fd_cache {
            let mut c = cache.lock().unwrap();
            c.clock += 1;
            let now = c.clock;
            if let Some((f, stamp)) = c.files.get_mut(&path) {
                *stamp = now;
                return Ok(Arc::clone(f));
            }
        }
        Ok(Arc::new(File::open(path)?))
    }

    /// Cached member fds (test/introspection hook).
    pub fn cached_fds(&self) -> usize {
        self.fd_cache
            .as_ref()
            .map_or(0, |c| c.lock().unwrap().files.len())
    }

    /// Append to the per-device allocation journal — the analog of
    /// ext4's metadata/journal write on block allocation.
    fn journal(&self, dev: usize, key: &str, len: usize) -> anyhow::Result<()> {
        let _guard = self.meta.lock().unwrap();
        let mut j = OpenOptions::new()
            .create(true)
            .append(true)
            .open(self.devices[dev].join("journal.meta"))?;
        writeln!(j, "{key} {len}")?;
        j.sync_data()?; // journaling is synchronous — the §III-D tax
        Ok(())
    }

    /// Stripe layout: chunk c goes to device c % n at intra-file offset
    /// (c / n) * stripe. Returns each member's (dev_offset, chunk)
    /// list, in chunk order per member.
    fn member_chunks<'d>(&self, data: &'d [u8]) -> Vec<Vec<(u64, &'d [u8])>> {
        let n = self.devices.len();
        let mut per_dev: Vec<Vec<(u64, &[u8])>> = (0..n).map(|_| Vec::new()).collect();
        let mut c = 0usize;
        let mut off = 0usize;
        while off < data.len() {
            let len = self.stripe.min(data.len() - off);
            per_dev[c % n]
                .push((((c / n) * self.stripe) as u64, &data[off..off + len]));
            off += len;
            c += 1;
        }
        per_dev
    }

    /// Stripe parts overlapped by logical window `[offset, offset+len)`:
    /// (device, device byte offset, window-relative range) per touched
    /// chunk, in logical order.
    fn window_parts(
        &self,
        offset: usize,
        len: usize,
    ) -> Vec<(usize, u64, std::ops::Range<usize>)> {
        let n = self.devices.len();
        let end = offset + len;
        let mut parts = Vec::new();
        let mut c = offset / self.stripe;
        while c * self.stripe < end {
            let cs = c * self.stripe;
            let lo = offset.max(cs);
            let hi = end.min(cs + self.stripe);
            if lo < hi {
                parts.push((
                    c % n,
                    ((c / n) * self.stripe + (lo - cs)) as u64,
                    lo - offset..hi - offset,
                ));
            }
            c += 1;
        }
        parts
    }

    /// [`Self::member_chunks`] for a destination buffer: disjoint
    /// mutable chunk slices grouped per member device.
    fn member_chunks_mut<'d>(
        &self,
        out: &'d mut [u8],
    ) -> Vec<Vec<(u64, &'d mut [u8])>> {
        let n = self.devices.len();
        let mut per_dev: Vec<Vec<(u64, &mut [u8])>> =
            (0..n).map(|_| Vec::new()).collect();
        let total = out.len();
        let mut rest = out;
        let mut c = 0usize;
        let mut off = 0usize;
        while off < total {
            let len = self.stripe.min(total - off);
            let (head, tail) = rest.split_at_mut(len);
            per_dev[c % n].push((((c / n) * self.stripe) as u64, head));
            rest = tail;
            off += len;
            c += 1;
        }
        per_dev
    }
}

fn sanitize(key: &str) -> String {
    key.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '.' || c == '-' { c } else { '_' })
        .collect()
}

impl NvmeEngine for FsEngine {
    fn write(&self, key: &str, data: &[u8]) -> anyhow::Result<()> {
        let t0 = Instant::now();
        let busy = self.stats.busy_guard();
        let n = self.devices.len();
        // open (or create) each member file — path resolution per call
        // unless the fd cache absorbs it
        let files: Vec<Arc<File>> = (0..n)
            .map(|d| self.open_rw(key, d))
            .collect::<anyhow::Result<_>>()?;
        let fresh = self.len_of(key) != Some(data.len());
        // data path: member chunk lists issued concurrently (RAID0)
        io_scope(|s| {
            for (d, chunks) in self.member_chunks(data).into_iter().enumerate() {
                if chunks.is_empty() {
                    continue;
                }
                let file = &files[d];
                let stats = &self.stats;
                s.submit(&self.queues[d], move || {
                    let _q = stats.queue_guard(d);
                    for (dev_off, chunk) in chunks {
                        file.write_all_at(chunk, dev_off)?;
                    }
                    Ok(())
                });
            }
            Ok(())
        })?;
        for (d, f) in files.iter().enumerate() {
            f.sync_data()?;
            if fresh {
                // block allocation changed -> metadata/journal update
                self.journal(d, key, data.len())?;
            }
        }
        // record logical length (the "file size" metadata)
        {
            let _guard = self.meta.lock().unwrap();
            std::fs::write(
                self.devices[0].join(format!("{}.len", sanitize(key))),
                data.len().to_string(),
            )?;
        }
        drop(busy);
        self.stats.record_write(data.len() as u64, t0.elapsed().as_nanos() as u64);
        Ok(())
    }

    fn read(&self, key: &str, out: &mut [u8]) -> anyhow::Result<()> {
        let t0 = Instant::now();
        let busy = self.stats.busy_guard();
        let stored = self
            .len_of(key)
            .ok_or_else(|| anyhow::anyhow!("fs_engine: no tensor '{key}'"))?;
        anyhow::ensure!(
            stored == out.len(),
            "fs_engine: '{key}' stored {stored} B, requested {} B",
            out.len()
        );
        let n = self.devices.len();
        let out_len = out.len() as u64;
        let files: Vec<Arc<File>> = (0..n)
            .map(|d| self.open_ro(key, d))
            .collect::<anyhow::Result<_>>()?;
        io_scope(|s| {
            for (d, chunks) in self.member_chunks_mut(out).into_iter().enumerate() {
                if chunks.is_empty() {
                    continue;
                }
                let file = &files[d];
                let stats = &self.stats;
                s.submit(&self.queues[d], move || {
                    let _q = stats.queue_guard(d);
                    for (dev_off, chunk) in chunks {
                        file.read_exact_at(chunk, dev_off)?;
                    }
                    Ok(())
                });
            }
            Ok(())
        })?;
        drop(busy);
        self.stats.record_read(out_len, t0.elapsed().as_nanos() as u64);
        Ok(())
    }

    fn read_at(&self, key: &str, offset: usize, out: &mut [u8]) -> anyhow::Result<()> {
        let t0 = Instant::now();
        let busy = self.stats.busy_guard();
        let stored = self
            .len_of(key)
            .ok_or_else(|| anyhow::anyhow!("fs_engine: no tensor '{key}'"))?;
        anyhow::ensure!(
            offset + out.len() <= stored,
            "fs_engine: ranged read past '{key}' ({offset}+{} > {stored})",
            out.len()
        );
        let out_len = out.len() as u64;
        // serial member preads on the caller thread: a tile touches one
        // or two stripe chunks, not worth the fan-out
        let mut opened: HashMap<usize, Arc<File>> = HashMap::new();
        for (d, dev_off, range) in self.window_parts(offset, out.len()) {
            let file = match opened.get(&d) {
                Some(f) => Arc::clone(f),
                None => {
                    let f = self.open_ro(key, d)?;
                    opened.insert(d, Arc::clone(&f));
                    f
                }
            };
            let _q = self.stats.queue_guard(d);
            file.read_exact_at(&mut out[range], dev_off)?;
        }
        drop(busy);
        self.stats.record_read(out_len, t0.elapsed().as_nanos() as u64);
        Ok(())
    }

    fn write_at(&self, key: &str, offset: usize, data: &[u8]) -> anyhow::Result<()> {
        let t0 = Instant::now();
        let busy = self.stats.busy_guard();
        let stored = self
            .len_of(key)
            .ok_or_else(|| anyhow::anyhow!("fs_engine: no tensor '{key}'"))?;
        anyhow::ensure!(
            offset + data.len() <= stored,
            "fs_engine: ranged write past '{key}' ({offset}+{} > {stored})",
            data.len()
        );
        let mut opened: HashMap<usize, Arc<File>> = HashMap::new();
        for (d, dev_off, range) in self.window_parts(offset, data.len()) {
            let file = match opened.get(&d) {
                Some(f) => Arc::clone(f),
                None => {
                    let f = self.open_rw(key, d)?;
                    opened.insert(d, Arc::clone(&f));
                    f
                }
            };
            let _q = self.stats.queue_guard(d);
            file.write_all_at(&data[range], dev_off)?;
        }
        // in-place rewrite: length and allocation are unchanged, so no
        // journal append — and no per-tile sync either (syncing every
        // tile would multiply the fsync tax by the tile count); callers
        // needing durability take one explicit `flush` per key
        drop(busy);
        self.stats.record_write(data.len() as u64, t0.elapsed().as_nanos() as u64);
        Ok(())
    }

    fn flush(&self, key: &str) -> anyhow::Result<()> {
        if self.len_of(key).is_none() {
            return Ok(());
        }
        for d in 0..self.devices.len() {
            self.open_ro(key, d)?.sync_data()?;
        }
        Ok(())
    }

    fn reserve(&self, key: &str, len: usize) -> anyhow::Result<()> {
        if let Some(stored) = self.len_of(key) {
            anyhow::ensure!(
                stored == len,
                "fs_engine: reserve size change for '{key}' ({stored} -> {len}) unsupported"
            );
            return Ok(());
        }
        // allocate member files sparsely (set_len) and pay the same
        // metadata taxes a fresh write pays: journal + length record
        let n = self.devices.len();
        let mut member_len = vec![0u64; n];
        for (d, dev_off, range) in self.window_parts(0, len) {
            member_len[d] = member_len[d].max(dev_off + range.len() as u64);
        }
        for d in 0..n {
            let f = self.open_rw(key, d)?;
            f.set_len(member_len[d])?;
            self.journal(d, key, len)?;
        }
        {
            let _guard = self.meta.lock().unwrap();
            std::fs::write(
                self.devices[0].join(format!("{}.len", sanitize(key))),
                len.to_string(),
            )?;
        }
        Ok(())
    }

    fn len_of(&self, key: &str) -> Option<usize> {
        let p = self.devices[0].join(format!("{}.len", sanitize(key)));
        std::fs::read_to_string(p).ok()?.trim().parse().ok()
    }

    fn stats(&self) -> IoSnapshot {
        self.stats.snapshot()
    }

    fn label(&self) -> &'static str {
        if self.fd_cache.is_some() {
            "fs-raid0-cachedfd"
        } else {
            "fs-raid0"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ma-fs-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_across_stripes() {
        let dir = tmpdir("rt");
        let eng = FsEngine::new(&dir, 3, 4096).unwrap();
        let data: Vec<u8> = (0..20_000).map(|i| (i % 251) as u8).collect();
        eng.write("layers.0.wq/fp16", &data).unwrap();
        let mut out = vec![0u8; data.len()];
        eng.read("layers.0.wq/fp16", &mut out).unwrap();
        assert_eq!(out, data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn overwrite_same_size_skips_journal_growth() {
        let dir = tmpdir("ow");
        let eng = FsEngine::new(&dir, 2, 4096).unwrap();
        eng.write("t", &[1u8; 9000]).unwrap();
        let j1 = std::fs::metadata(dir.join("dev0/journal.meta")).unwrap().len();
        eng.write("t", &[2u8; 9000]).unwrap(); // steady-state overwrite
        let j2 = std::fs::metadata(dir.join("dev0/journal.meta")).unwrap().len();
        assert_eq!(j1, j2, "no re-allocation on same-size overwrite");
        let mut out = vec![0u8; 9000];
        eng.read("t", &mut out).unwrap();
        assert!(out.iter().all(|&b| b == 2));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn read_wrong_size_errors() {
        let dir = tmpdir("sz");
        let eng = FsEngine::new(&dir, 1, 4096).unwrap();
        eng.write("t", &[0u8; 100]).unwrap();
        let mut out = vec![0u8; 50];
        assert!(eng.read("t", &mut out).is_err());
        assert!(eng.read("missing", &mut out).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stats_accumulate() {
        let dir = tmpdir("st");
        let eng = FsEngine::new(&dir, 2, 4096).unwrap();
        eng.write("a", &[0u8; 5000]).unwrap();
        let mut out = vec![0u8; 5000];
        eng.read("a", &mut out).unwrap();
        let s = eng.stats();
        assert_eq!(s.writes, 1);
        assert_eq!(s.reads, 1);
        assert_eq!(s.bytes_written, 5000);
        assert_eq!(s.bytes_read, 5000);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cached_fd_variant_roundtrips_and_reuses_handles() {
        let dir = tmpdir("cfd");
        let eng = FsEngine::with_fd_cache(&dir, 2, 4096, true).unwrap();
        assert_eq!(eng.label(), "fs-raid0-cachedfd");
        let data: Vec<u8> = (0..20_000).map(|i| (i % 241) as u8).collect();
        eng.write("t", &data).unwrap();
        let opened = eng.cached_fds();
        assert_eq!(opened, 2, "one cached fd per member device");
        // overwrite + read reuse the cached handles — no new opens
        eng.write("t", &data).unwrap();
        let mut out = vec![0u8; data.len()];
        eng.read("t", &mut out).unwrap();
        assert_eq!(out, data);
        assert_eq!(eng.cached_fds(), opened);
        // journal behaviour is unchanged: same-size overwrite adds none
        let j1 = std::fs::metadata(dir.join("dev0/journal.meta")).unwrap().len();
        eng.write("t", &data).unwrap();
        let j2 = std::fs::metadata(dir.join("dev0/journal.meta")).unwrap().len();
        assert_eq!(j1, j2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn keys_with_slashes_are_sanitized() {
        let dir = tmpdir("kx");
        let eng = FsEngine::new(&dir, 1, 4096).unwrap();
        eng.write("layers.0/wq::fp16", &[7u8; 64]).unwrap();
        let mut out = vec![0u8; 64];
        eng.read("layers.0/wq::fp16", &mut out).unwrap();
        assert_eq!(out, [7u8; 64]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
