//! Filesystem-based SSD engine — the DeepNVMe/ZeRO-Infinity baseline.
//!
//! Each tensor is a separate file (paper §III-D: "each tensor is
//! offloaded to a separate file, allowing file systems such as ext4 to
//! manage storage allocation").  Multiple devices are emulated as
//! directory roots joined in software RAID0: the tensor's bytes are
//! striped across per-device segment files at `stripe` granularity,
//! which is exactly what md-RAID0 + one-file-per-tensor does at block
//! level.  Every call pays the filesystem taxes the paper measures:
//! path resolution, open/create, metadata updates, and fsync-backed
//! allocation-table writes.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Instant;

use super::{IoSnapshot, IoStats, NvmeEngine};

pub struct FsEngine {
    devices: Vec<PathBuf>,
    stripe: usize,
    stats: IoStats,
    /// Directory metadata mutex: ext4 serializes directory updates; the
    /// journal file emulates its metadata/allocation writes.
    meta: Mutex<()>,
}

impl FsEngine {
    /// `root/devN/` stands in for each ext4-formatted SSD. `stripe` is
    /// the RAID0 chunk size (md default 512 KiB).
    pub fn new(root: &std::path::Path, devices: usize, stripe: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(devices >= 1 && stripe >= 4096);
        let devs: Vec<PathBuf> = (0..devices).map(|i| root.join(format!("dev{i}"))).collect();
        for d in &devs {
            std::fs::create_dir_all(d)?;
        }
        Ok(Self { devices: devs, stripe, stats: IoStats::default(), meta: Mutex::new(()) })
    }

    fn seg_path(&self, key: &str, dev: usize) -> PathBuf {
        // one file per tensor per device (its RAID0 member extent)
        self.devices[dev].join(format!("{}.seg", sanitize(key)))
    }

    /// Append to the per-device allocation journal — the analog of
    /// ext4's metadata/journal write on block allocation.
    fn journal(&self, dev: usize, key: &str, len: usize) -> anyhow::Result<()> {
        let _guard = self.meta.lock().unwrap();
        let mut j = OpenOptions::new()
            .create(true)
            .append(true)
            .open(self.devices[dev].join("journal.meta"))?;
        writeln!(j, "{key} {len}")?;
        j.sync_data()?; // journaling is synchronous — the §III-D tax
        Ok(())
    }

    /// Stripe layout: chunk c goes to device c % n at intra-file offset
    /// (c / n) * stripe.
    fn for_each_stripe(
        &self,
        total: usize,
        mut f: impl FnMut(usize, usize, usize, usize) -> anyhow::Result<()>,
    ) -> anyhow::Result<()> {
        let n = self.devices.len();
        let mut c = 0usize;
        let mut off = 0usize;
        while off < total {
            let len = self.stripe.min(total - off);
            let dev = c % n;
            let dev_off = (c / n) * self.stripe;
            f(dev, dev_off, off, len)?;
            off += len;
            c += 1;
        }
        Ok(())
    }
}

fn sanitize(key: &str) -> String {
    key.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '.' || c == '-' { c } else { '_' })
        .collect()
}

impl NvmeEngine for FsEngine {
    fn write(&self, key: &str, data: &[u8]) -> anyhow::Result<()> {
        let t0 = Instant::now();
        let n = self.devices.len();
        // open (or create) each member file — path resolution per call
        let mut files: Vec<File> = (0..n)
            .map(|d| {
                OpenOptions::new()
                    .create(true)
                    .write(true)
                    .truncate(false)
                    .open(self.seg_path(key, d))
                    .map_err(Into::into)
            })
            .collect::<anyhow::Result<_>>()?;
        let fresh = self.len_of(key) != Some(data.len());
        self.for_each_stripe(data.len(), |dev, dev_off, off, len| {
            files[dev].seek(SeekFrom::Start(dev_off as u64))?;
            files[dev].write_all(&data[off..off + len])?;
            Ok(())
        })?;
        for (d, f) in files.iter().enumerate() {
            f.sync_data()?;
            if fresh {
                // block allocation changed -> metadata/journal update
                self.journal(d, key, data.len())?;
            }
        }
        // record logical length (the "file size" metadata)
        {
            let _guard = self.meta.lock().unwrap();
            std::fs::write(
                self.devices[0].join(format!("{}.len", sanitize(key))),
                data.len().to_string(),
            )?;
        }
        self.stats.record_write(data.len() as u64, t0.elapsed().as_nanos() as u64);
        Ok(())
    }

    fn read(&self, key: &str, out: &mut [u8]) -> anyhow::Result<()> {
        let t0 = Instant::now();
        let stored = self
            .len_of(key)
            .ok_or_else(|| anyhow::anyhow!("fs_engine: no tensor '{key}'"))?;
        anyhow::ensure!(
            stored == out.len(),
            "fs_engine: '{key}' stored {stored} B, requested {} B",
            out.len()
        );
        let n = self.devices.len();
        let mut files: Vec<File> = (0..n)
            .map(|d| File::open(self.seg_path(key, d)).map_err(Into::into))
            .collect::<anyhow::Result<_>>()?;
        self.for_each_stripe(out.len(), |dev, dev_off, off, len| {
            files[dev].seek(SeekFrom::Start(dev_off as u64))?;
            files[dev].read_exact(&mut out[off..off + len])?;
            Ok(())
        })?;
        self.stats.record_read(out.len() as u64, t0.elapsed().as_nanos() as u64);
        Ok(())
    }

    fn len_of(&self, key: &str) -> Option<usize> {
        let p = self.devices[0].join(format!("{}.len", sanitize(key)));
        std::fs::read_to_string(p).ok()?.trim().parse().ok()
    }

    fn stats(&self) -> IoSnapshot {
        self.stats.snapshot()
    }

    fn label(&self) -> &'static str {
        "fs-raid0"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ma-fs-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_across_stripes() {
        let dir = tmpdir("rt");
        let eng = FsEngine::new(&dir, 3, 4096).unwrap();
        let data: Vec<u8> = (0..20_000).map(|i| (i % 251) as u8).collect();
        eng.write("layers.0.wq/fp16", &data).unwrap();
        let mut out = vec![0u8; data.len()];
        eng.read("layers.0.wq/fp16", &mut out).unwrap();
        assert_eq!(out, data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn overwrite_same_size_skips_journal_growth() {
        let dir = tmpdir("ow");
        let eng = FsEngine::new(&dir, 2, 4096).unwrap();
        eng.write("t", &[1u8; 9000]).unwrap();
        let j1 = std::fs::metadata(dir.join("dev0/journal.meta")).unwrap().len();
        eng.write("t", &[2u8; 9000]).unwrap(); // steady-state overwrite
        let j2 = std::fs::metadata(dir.join("dev0/journal.meta")).unwrap().len();
        assert_eq!(j1, j2, "no re-allocation on same-size overwrite");
        let mut out = vec![0u8; 9000];
        eng.read("t", &mut out).unwrap();
        assert!(out.iter().all(|&b| b == 2));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn read_wrong_size_errors() {
        let dir = tmpdir("sz");
        let eng = FsEngine::new(&dir, 1, 4096).unwrap();
        eng.write("t", &[0u8; 100]).unwrap();
        let mut out = vec![0u8; 50];
        assert!(eng.read("t", &mut out).is_err());
        assert!(eng.read("missing", &mut out).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stats_accumulate() {
        let dir = tmpdir("st");
        let eng = FsEngine::new(&dir, 2, 4096).unwrap();
        eng.write("a", &[0u8; 5000]).unwrap();
        let mut out = vec![0u8; 5000];
        eng.read("a", &mut out).unwrap();
        let s = eng.stats();
        assert_eq!(s.writes, 1);
        assert_eq!(s.reads, 1);
        assert_eq!(s.bytes_written, 5000);
        assert_eq!(s.bytes_read, 5000);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn keys_with_slashes_are_sanitized() {
        let dir = tmpdir("kx");
        let eng = FsEngine::new(&dir, 1, 4096).unwrap();
        eng.write("layers.0/wq::fp16", &[7u8; 64]).unwrap();
        let mut out = vec![0u8; 64];
        eng.read("layers.0/wq::fp16", &mut out).unwrap();
        assert_eq!(out, [7u8; 64]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
