//! Device-health tracking: latency EWMA + rolling p99, error/timeout
//! accounting, and a quarantine state machine.
//!
//! One [`HealthTracker`] rides each [`crate::ssd::IoExecutor`] (the
//! shared submission pool fronting the device queues).  The async
//! engine records every op's service latency and outcome here; when
//! per-op deadlines are enabled (`TrainSpec::io_deadline_ms`), the
//! waiter uses [`HealthTracker::hedge_delay`] to decide when a stalled
//! read should be hedged with a re-submission on the same queue.
//!
//! The quarantine state machine is rate-driven: once the bad-op
//! fraction (errors + timeouts) over the rolling window crosses
//! [`HealthConfig::degrade_frac`], the device is marked degraded and a
//! typed [`EventKind::DeviceDegraded`] event is emitted; the fleet and
//! pipeline governors read [`HealthTracker::is_degraded`] and shrink
//! depth/prefetch against it.  A streak of
//! [`HealthConfig::cooldown_ops`] clean ops re-probes the device back
//! to healthy and emits [`EventKind::DeviceRecovered`].

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::events::{Event, EventKind, EventSink, JobId};

/// Latency samples needed before the hedge delay trusts the rolling
/// percentile instead of falling back to the full deadline.
const MIN_SAMPLES: usize = 16;

#[derive(Debug, Clone, Copy)]
pub struct HealthConfig {
    /// Rolling latency samples kept for the p99 estimate, and the
    /// op-count span of the bad-rate window.
    pub window: usize,
    /// Ops observed before the quarantine check can trigger.
    pub min_ops: u64,
    /// Bad-op fraction (errors + timeouts over the window) at which
    /// the device quarantines.
    pub degrade_frac: f64,
    /// Consecutive clean ops while quarantined before the device
    /// re-probes back to healthy.
    pub cooldown_ops: u64,
}

impl Default for HealthConfig {
    fn default() -> Self {
        Self { window: 128, min_ops: 16, degrade_frac: 0.25, cooldown_ops: 64 }
    }
}

#[derive(Debug)]
enum State {
    Healthy,
    Quarantined { clean: u64 },
}

#[derive(Debug)]
struct Inner {
    /// Ring of recent service latencies (ns) for the p99 estimate.
    ring: Vec<u64>,
    cursor: usize,
    window_ops: u64,
    window_bad: u64,
    state: State,
}

/// Per-device health: EWMA/p99 latency, error/timeout/hedge meters,
/// and the quarantine state machine (see module docs).
pub struct HealthTracker {
    cfg: HealthConfig,
    /// EWMA of service latency in ns (alpha = 1/8; 0 = no samples).
    ewma_ns: AtomicU64,
    ops: AtomicU64,
    errors: AtomicU64,
    timeouts: AtomicU64,
    hedges: AtomicU64,
    degraded: AtomicBool,
    inner: Mutex<Inner>,
    sink: Mutex<Option<Arc<dyn EventSink>>>,
}

impl HealthTracker {
    pub fn new(cfg: HealthConfig) -> Self {
        Self {
            cfg,
            ewma_ns: AtomicU64::new(0),
            ops: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            hedges: AtomicU64::new(0),
            degraded: AtomicBool::new(false),
            inner: Mutex::new(Inner {
                ring: Vec::new(),
                cursor: 0,
                window_ops: 0,
                window_bad: 0,
                state: State::Healthy,
            }),
            sink: Mutex::new(None),
        }
    }

    /// Route quarantine transitions ([`EventKind::DeviceDegraded`] /
    /// [`EventKind::DeviceRecovered`]) to `sink`.
    pub fn set_sink(&self, sink: Arc<dyn EventSink>) {
        *self.sink.lock().unwrap() = Some(sink);
    }

    pub fn ops(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }

    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    /// Ops whose primary submission outlived its hedge deadline.
    pub fn timeouts(&self) -> u64 {
        self.timeouts.load(Ordering::Relaxed)
    }

    /// Hedged re-submissions issued.
    pub fn hedges(&self) -> u64 {
        self.hedges.load(Ordering::Relaxed)
    }

    pub fn ewma_ns(&self) -> u64 {
        self.ewma_ns.load(Ordering::Relaxed)
    }

    /// Cheap flag for the governors: true while quarantined.
    pub fn is_degraded(&self) -> bool {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Rolling p99 of service latency in ns (0 with no samples).
    pub fn p99_ns(&self) -> u64 {
        let inner = self.inner.lock().unwrap();
        percentile(&inner.ring, 99)
    }

    /// How long a waiter should give the primary submission before
    /// hedging: `min(deadline, max(4×EWMA, p99))` once enough samples
    /// exist, else the full deadline.  Never below `deadline / 8`, so
    /// a microsecond-scale p99 can't turn routine queue waits into a
    /// hedge storm.
    pub fn hedge_delay(&self, deadline: Duration) -> Duration {
        let inner = self.inner.lock().unwrap();
        if inner.ring.len() < MIN_SAMPLES {
            return deadline;
        }
        let p99 = percentile(&inner.ring, 99);
        let guess = p99.max(self.ewma_ns().saturating_mul(4));
        let floor = deadline / 8;
        Duration::from_nanos(guess).clamp(floor, deadline)
    }

    /// Record one completed op's service latency and outcome.
    pub fn record(&self, latency: Duration, ok: bool) {
        let ns = latency.as_nanos() as u64;
        self.ops.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        let prev = self.ewma_ns.load(Ordering::Relaxed);
        let next = if prev == 0 { ns } else { prev - prev / 8 + ns / 8 };
        self.ewma_ns.store(next, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        let cursor = inner.cursor;
        if inner.ring.len() < self.cfg.window {
            inner.ring.push(ns);
        } else {
            inner.ring[cursor] = ns;
        }
        inner.cursor = (cursor + 1) % self.cfg.window;
        self.observe_outcome(&mut inner, ok);
    }

    /// Record a primary submission outliving its hedge deadline (the
    /// op itself is still recorded when it eventually completes).
    pub fn record_timeout(&self) {
        self.timeouts.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        self.observe_outcome(&mut inner, false);
    }

    /// Record a hedged re-submission being issued.
    pub fn record_hedge(&self) {
        self.hedges.fetch_add(1, Ordering::Relaxed);
    }

    fn observe_outcome(&self, inner: &mut Inner, ok: bool) {
        match inner.state {
            State::Healthy => {
                inner.window_ops += 1;
                if !ok {
                    inner.window_bad += 1;
                }
                let rate = inner.window_bad as f64 / inner.window_ops as f64;
                if inner.window_ops >= self.cfg.min_ops && rate >= self.cfg.degrade_frac {
                    inner.state = State::Quarantined { clean: 0 };
                    self.degraded.store(true, Ordering::Relaxed);
                    self.emit(EventKind::DeviceDegraded {
                        errors: self.errors(),
                        timeouts: self.timeouts(),
                    });
                    inner.window_ops = 0;
                    inner.window_bad = 0;
                } else if inner.window_ops >= self.cfg.window as u64 {
                    // decay the window so old trouble ages out
                    inner.window_ops /= 2;
                    inner.window_bad /= 2;
                }
            }
            State::Quarantined { ref mut clean } => {
                if ok {
                    *clean += 1;
                    if *clean >= self.cfg.cooldown_ops {
                        inner.state = State::Healthy;
                        self.degraded.store(false, Ordering::Relaxed);
                        self.emit(EventKind::DeviceRecovered);
                    }
                } else {
                    *clean = 0;
                }
            }
        }
    }

    fn emit(&self, kind: EventKind) {
        let sink = self.sink.lock().unwrap().clone();
        if let Some(sink) = sink {
            let detail = format!(
                "ops {} errors {} timeouts {} ewma {}us",
                self.ops(),
                self.errors(),
                self.timeouts(),
                self.ewma_ns() / 1000
            );
            sink.emit(Event { job: JobId::HOST, kind, detail });
        }
    }
}

impl Default for HealthTracker {
    fn default() -> Self {
        Self::new(HealthConfig::default())
    }
}

fn percentile(samples: &[u64], pct: usize) -> u64 {
    if samples.is_empty() {
        return 0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_unstable();
    sorted[(sorted.len() * pct / 100).min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::events::MemorySink;

    #[test]
    fn ewma_and_p99_track_service_latency() {
        let h = HealthTracker::default();
        for _ in 0..100 {
            h.record(Duration::from_micros(100), true);
        }
        for _ in 0..2 {
            h.record(Duration::from_millis(50), true);
        }
        let ewma = h.ewma_ns();
        assert!(ewma > 100_000, "ewma {ewma} ignored the spikes");
        assert!(ewma < 50_000_000, "ewma {ewma} forgot the baseline");
        assert_eq!(h.p99_ns(), 50_000_000);
        assert_eq!(h.ops(), 102);
    }

    #[test]
    fn hedge_delay_clamps_between_floor_and_deadline() {
        let h = HealthTracker::default();
        let d = Duration::from_millis(80);
        // no samples yet: wait the whole deadline
        assert_eq!(h.hedge_delay(d), d);
        for _ in 0..64 {
            h.record(Duration::from_micros(50), true);
        }
        // p99 far below the floor: clamp up to deadline/8
        assert_eq!(h.hedge_delay(d), d / 8);
        for _ in 0..64 {
            h.record(Duration::from_secs(1), true);
        }
        // p99 far above the deadline: clamp down
        assert_eq!(h.hedge_delay(d), d);
    }

    #[test]
    fn error_burst_quarantines_and_clean_streak_recovers() {
        let sink = MemorySink::new();
        let h = HealthTracker::new(HealthConfig {
            min_ops: 8,
            cooldown_ops: 8,
            ..Default::default()
        });
        h.set_sink(sink.clone());
        assert!(!h.is_degraded());
        for _ in 0..4 {
            h.record(Duration::from_micros(10), true);
        }
        for _ in 0..4 {
            h.record(Duration::from_micros(10), false);
        }
        assert!(h.is_degraded(), "50% bad over min_ops must quarantine");
        assert_eq!(h.errors(), 4);
        // a clean streak with one blip in the middle restarts cooldown
        for i in 0..12 {
            h.record(Duration::from_micros(10), i != 3);
        }
        assert!(!h.is_degraded(), "clean streak must re-probe healthy");
        let evs = sink.events();
        assert!(matches!(evs[0].kind, EventKind::DeviceDegraded { errors: 4, .. }));
        assert!(matches!(evs[1].kind, EventKind::DeviceRecovered));
        assert_eq!(evs.len(), 2);
    }

    #[test]
    fn timeouts_count_toward_quarantine() {
        let h = HealthTracker::new(HealthConfig { min_ops: 8, ..Default::default() });
        for _ in 0..6 {
            h.record(Duration::from_micros(10), true);
        }
        for _ in 0..2 {
            h.record_timeout();
        }
        assert!(h.is_degraded());
        assert_eq!(h.timeouts(), 2);
    }
}
