//! Checksummed-stream integrity: per-block FNV-1a sums in a sidecar
//! key, verified on every read.
//!
//! [`IntegrityEngine`] is an [`NvmeEngine`] decorator.  Its position in
//! the stack is a contract, not a convenience (see the [`crate::ssd`]
//! module docs for the full ordering):
//!
//! - **below [`crate::ssd::RetryEngine`]** — a detected mismatch
//!   surfaces as an ordinary retryable error, so transient corruption
//!   (a bad DMA, a misread) heals by re-read while durable rot
//!   exhausts the budget and aborts with the typed error intact;
//! - **above any fault injection** ([`crate::ssd::FaultyEngine`]) —
//!   injected bit flips are *caught*, which is what makes every chaos
//!   path testable;
//! - **above [`crate::jobs::ScopedEngine`]** — the sidecar key rides
//!   the same job prefix as its data key, so tenants' sums are
//!   isolated exactly like their streams;
//! - **below [`crate::ckpt::ShadowEngine`]** — both physical extents
//!   of every shadow-paged stream carry their own sums, so a committed
//!   epoch stays verifiable while the live extent churns.
//!
//! Sums cover fixed [`BLOCK_BYTES`] blocks and live under
//! `sums/{key}` ([`sums_key`]); sidecar keys themselves pass through
//! unchecksummed (no recursion).  Keys written before the layer was
//! enabled have no sidecar and read back unverified, so turning
//! `--verify-reads` on over an existing store is safe.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use super::{IoSnapshot, NvmeEngine};
use crate::util::events::{Event, EventKind, EventSink, JobId};

/// Fixed checksum granule: one FNV-1a sum per 256 KiB of stored data
/// (the tail block of a key may be shorter).
pub const BLOCK_BYTES: usize = 256 << 10;

/// Sidecar key prefix; `sums_key("k")` = `"sums/k"`.
pub const SUMS_PREFIX: &str = "sums/";

/// Key-hash stripes for the per-key read/write locks that keep a
/// block's data and its sum atomic with respect to each other.
const LOCK_STRIPES: usize = 64;

/// 64-bit FNV-1a over `data` — cheap, dependency-free, and plenty to
/// make a single flipped bit detectable with certainty.
pub fn fnv1a(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in data {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Sidecar key holding `key`'s per-block sums.
pub fn sums_key(key: &str) -> String {
    format!("{SUMS_PREFIX}{key}")
}

fn is_sidecar(key: &str) -> bool {
    key.starts_with(SUMS_PREFIX)
}

fn encode_sums(sums: &[u64]) -> Vec<u8> {
    let mut raw = Vec::with_capacity(sums.len() * 8);
    for s in sums {
        raw.extend_from_slice(&s.to_le_bytes());
    }
    raw
}

fn decode_sums(raw: &[u8]) -> Vec<u64> {
    raw.chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

/// Typed checksum-mismatch error: `key`'s block `block` read back with
/// sum `got` where the sidecar says `expected`.  Surfaced through
/// `anyhow`, so callers can `downcast_ref::<IntegrityError>()`; the
/// retry layer treats it like any other fault (re-read).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IntegrityError {
    pub key: String,
    pub block: usize,
    pub expected: u64,
    pub got: u64,
}

impl fmt::Display for IntegrityError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "integrity mismatch on '{}' block {}: expected {:016x}, got {:016x}",
            self.key, self.block, self.expected, self.got
        )
    }
}

impl std::error::Error for IntegrityError {}

/// Checksumming [`NvmeEngine`] decorator (see module docs for the
/// stack-position contract).  Every write path maintains the sidecar;
/// every read path verifies the blocks it touched and surfaces
/// [`IntegrityError`] on mismatch, metered in
/// [`IoSnapshot::integrity_failures`].
pub struct IntegrityEngine {
    inner: Arc<dyn NvmeEngine>,
    /// Striped per-key locks: writers hold the write side across
    /// data-write + sum-update so a concurrent read can never pair new
    /// bytes with an old sum; readers hold the read side, so reads
    /// stay concurrent with each other.
    locks: Vec<RwLock<()>>,
    job: JobId,
    failures: AtomicU64,
    scrubbed_bytes: AtomicU64,
    scrub_failures: AtomicU64,
    sink: Mutex<Option<Arc<dyn EventSink>>>,
}

impl IntegrityEngine {
    pub fn new(inner: Arc<dyn NvmeEngine>) -> Self {
        Self {
            inner,
            locks: (0..LOCK_STRIPES).map(|_| RwLock::new(())).collect(),
            job: JobId::HOST,
            failures: AtomicU64::new(0),
            scrubbed_bytes: AtomicU64::new(0),
            scrub_failures: AtomicU64::new(0),
            sink: Mutex::new(None),
        }
    }

    /// Tag emitted [`EventKind::IntegrityViolation`] events with `job`.
    pub fn for_job(mut self, job: JobId) -> Self {
        self.job = job;
        self
    }

    /// Route violation events (one per detected mismatch) to `sink`.
    pub fn set_sink(&self, sink: Arc<dyn EventSink>) {
        *self.sink.lock().unwrap() = Some(sink);
    }

    /// Detected mismatches so far.
    pub fn failures(&self) -> u64 {
        self.failures.load(Ordering::Relaxed)
    }

    /// Bytes verified by scrub passes so far.
    pub fn scrubbed_bytes(&self) -> u64 {
        self.scrubbed_bytes.load(Ordering::Relaxed)
    }

    /// Scrubbed keys that failed verification so far.
    pub fn scrub_failures(&self) -> u64 {
        self.scrub_failures.load(Ordering::Relaxed)
    }

    /// Verify every block of `key` by reading it back through the
    /// verify path; returns the bytes scrubbed (0 for an absent key).
    /// Failures are metered in [`IoSnapshot::scrub_failures`] and the
    /// mismatch is surfaced.
    pub fn scrub(&self, key: &str) -> anyhow::Result<u64> {
        let Some(len) = self.inner.len_of(key) else {
            return Ok(0);
        };
        let mut buf = vec![0u8; len];
        match self.read(key, &mut buf) {
            Ok(()) => {
                self.scrubbed_bytes.fetch_add(len as u64, Ordering::Relaxed);
                Ok(len as u64)
            }
            Err(e) => {
                self.scrub_failures.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Charge a scrub performed *through the stack above* (the trainer
    /// walks logical keys through the shadow layer; verification still
    /// happens here, but the byte accounting is the caller's).
    pub fn note_scrub(&self, bytes: u64, ok: bool) {
        if ok {
            self.scrubbed_bytes.fetch_add(bytes, Ordering::Relaxed);
        } else {
            self.scrub_failures.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn stripe(&self, key: &str) -> &RwLock<()> {
        &self.locks[(fnv1a(key.as_bytes()) as usize) % LOCK_STRIPES]
    }

    fn read_sums(&self, key: &str) -> anyhow::Result<Option<Vec<u64>>> {
        let sk = sums_key(key);
        let Some(len) = self.inner.len_of(&sk) else {
            return Ok(None);
        };
        let mut raw = vec![0u8; len];
        self.inner.read(&sk, &mut raw)?;
        Ok(Some(decode_sums(&raw)))
    }

    fn emit_violation(&self, err: &IntegrityError) {
        let sink = self.sink.lock().unwrap().clone();
        if let Some(sink) = sink {
            sink.emit(Event {
                job: self.job,
                kind: EventKind::IntegrityViolation {
                    key: err.key.clone(),
                    block: err.block,
                },
                detail: format!("expected {:016x}, got {:016x}", err.expected, err.got),
            });
        }
    }

    /// Verify `data` (which starts at block `first_block`'s boundary)
    /// against the sidecar sums.
    fn verify_span(
        &self,
        key: &str,
        first_block: usize,
        data: &[u8],
        sums: &[u64],
    ) -> anyhow::Result<()> {
        for (i, chunk) in data.chunks(BLOCK_BYTES).enumerate() {
            let block = first_block + i;
            let expected = *sums.get(block).ok_or_else(|| {
                anyhow::anyhow!("integrity sidecar for '{key}' truncated at block {block}")
            })?;
            let got = fnv1a(chunk);
            if got != expected {
                let err = IntegrityError { key: key.to_string(), block, expected, got };
                self.failures.fetch_add(1, Ordering::Relaxed);
                self.emit_violation(&err);
                return Err(err.into());
            }
        }
        Ok(())
    }
}

impl NvmeEngine for IntegrityEngine {
    fn write(&self, key: &str, data: &[u8]) -> anyhow::Result<()> {
        if is_sidecar(key) {
            return self.inner.write(key, data);
        }
        let _g = self.stripe(key).write().unwrap();
        self.inner.write(key, data)?;
        let sums: Vec<u64> = data.chunks(BLOCK_BYTES).map(fnv1a).collect();
        self.inner.write(&sums_key(key), &encode_sums(&sums))
    }

    fn read(&self, key: &str, out: &mut [u8]) -> anyhow::Result<()> {
        if is_sidecar(key) {
            return self.inner.read(key, out);
        }
        let _g = self.stripe(key).read().unwrap();
        self.inner.read(key, out)?;
        if let Some(sums) = self.read_sums(key)? {
            self.verify_span(key, 0, out, &sums)?;
        }
        Ok(())
    }

    fn read_at(&self, key: &str, offset: usize, out: &mut [u8]) -> anyhow::Result<()> {
        if is_sidecar(key) || out.is_empty() {
            return self.inner.read_at(key, offset, out);
        }
        let _g = self.stripe(key).read().unwrap();
        let Some(sums) = self.read_sums(key)? else {
            return self.inner.read_at(key, offset, out);
        };
        let stored = self
            .inner
            .len_of(key)
            .ok_or_else(|| anyhow::anyhow!("integrity: no tensor '{key}'"))?;
        anyhow::ensure!(
            offset + out.len() <= stored,
            "integrity: ranged read past '{key}' ({offset}+{} > {stored})",
            out.len()
        );
        // widen to block boundaries so whole blocks can be verified
        let first = offset / BLOCK_BYTES;
        let base = first * BLOCK_BYTES;
        let end = ((offset + out.len()).div_ceil(BLOCK_BYTES) * BLOCK_BYTES).min(stored);
        let mut tmp = vec![0u8; end - base];
        self.inner.read_at(key, base, &mut tmp)?;
        self.verify_span(key, first, &tmp, &sums)?;
        out.copy_from_slice(&tmp[offset - base..offset - base + out.len()]);
        Ok(())
    }

    fn write_at(&self, key: &str, offset: usize, data: &[u8]) -> anyhow::Result<()> {
        if is_sidecar(key) || data.is_empty() {
            return self.inner.write_at(key, offset, data);
        }
        let _g = self.stripe(key).write().unwrap();
        self.inner.write_at(key, offset, data)?;
        let sk = sums_key(key);
        let Some(side_len) = self.inner.len_of(&sk) else {
            // legacy key written before the layer was enabled: stays
            // unchecked rather than gaining a partial sidecar
            return Ok(());
        };
        let stored = self
            .inner
            .len_of(key)
            .ok_or_else(|| anyhow::anyhow!("integrity: no tensor '{key}'"))?;
        let first = offset / BLOCK_BYTES;
        let last = (offset + data.len() - 1) / BLOCK_BYTES;
        for b in first..=last {
            let bstart = b * BLOCK_BYTES;
            let bend = (bstart + BLOCK_BYTES).min(stored);
            // a fully-covered block sums straight from `data`; a
            // partially-covered edge block re-reads the merged bytes
            // (safe: we hold the key's write lock)
            let sum = if offset <= bstart && offset + data.len() >= bend {
                fnv1a(&data[bstart - offset..bend - offset])
            } else {
                let mut blk = vec![0u8; bend - bstart];
                self.inner.read_at(key, bstart, &mut blk)?;
                fnv1a(&blk)
            };
            anyhow::ensure!(
                (b + 1) * 8 <= side_len,
                "integrity sidecar for '{key}' shorter than block {b}"
            );
            self.inner.write_at(&sk, b * 8, &sum.to_le_bytes())?;
        }
        Ok(())
    }

    fn flush(&self, key: &str) -> anyhow::Result<()> {
        self.inner.flush(key)?;
        if !is_sidecar(key) {
            // flushing an absent sidecar is a no-op by contract
            self.inner.flush(&sums_key(key))?;
        }
        Ok(())
    }

    fn reserve(&self, key: &str, len: usize) -> anyhow::Result<()> {
        if is_sidecar(key) {
            return self.inner.reserve(key, len);
        }
        let _g = self.stripe(key).write().unwrap();
        let fresh = self.inner.len_of(key).is_none();
        self.inner.reserve(key, len)?;
        if fresh {
            // fresh reservations are all-zero by contract
            let zeros = vec![0u8; BLOCK_BYTES.min(len)];
            let nblocks = len.div_ceil(BLOCK_BYTES);
            let mut sums = vec![fnv1a(&zeros[..BLOCK_BYTES.min(len)]); nblocks];
            if nblocks > 0 {
                let tail = len - (nblocks - 1) * BLOCK_BYTES;
                sums[nblocks - 1] = fnv1a(&zeros[..tail]);
            }
            self.inner.write(&sums_key(key), &encode_sums(&sums))?;
        }
        Ok(())
    }

    fn len_of(&self, key: &str) -> Option<usize> {
        self.inner.len_of(key)
    }

    fn stats(&self) -> IoSnapshot {
        let mut s = self.inner.stats();
        s.integrity_failures += self.failures();
        s.scrubbed_bytes += self.scrubbed_bytes();
        s.scrub_failures += self.scrub_failures();
        s
    }

    fn label(&self) -> &'static str {
        self.inner.label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::ssd::{DirectEngine, RetryEngine, RetryPolicy};
    use crate::util::events::MemorySink;
    use crate::util::proptest::{check, Config};
    use crate::util::rng::Xoshiro256;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("ma-integ-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn direct(dir: &std::path::Path) -> Arc<DirectEngine> {
        Arc::new(DirectEngine::new(dir, 2, 1 << 24, 1).unwrap())
    }

    #[test]
    fn roundtrip_maintains_sums_and_label_passes_through() {
        let dir = tmpdir("rt");
        let base = direct(&dir);
        let eng = IntegrityEngine::new(base.clone());
        assert_eq!(eng.label(), base.label());
        let n = BLOCK_BYTES + 12_345;
        let data: Vec<u8> = (0..n).map(|i| (i % 251) as u8).collect();
        eng.write("w", &data).unwrap();
        // the sidecar exists below, one u64 per block
        assert_eq!(base.len_of(&sums_key("w")), Some(2 * 8));
        let mut out = vec![0u8; n];
        eng.read("w", &mut out).unwrap();
        assert_eq!(out, data);
        // ranged reads verify the blocks they touch
        for (off, len) in [(0usize, 1usize), (BLOCK_BYTES - 3, 7), (n - 9, 9)] {
            let mut out = vec![0u8; len];
            eng.read_at("w", off, &mut out).unwrap();
            assert_eq!(out, &data[off..off + len]);
        }
        assert_eq!(eng.failures(), 0);
        assert_eq!(eng.scrub("w").unwrap(), n as u64);
        assert_eq!(eng.scrubbed_bytes(), n as u64);
        assert_eq!(eng.scrub("absent").unwrap(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reserve_then_tile_writes_keep_sums_exact() {
        let dir = tmpdir("tile");
        let base = direct(&dir);
        let eng = IntegrityEngine::new(base);
        let n = 2 * BLOCK_BYTES + 999;
        eng.reserve("t", n).unwrap();
        eng.reserve("t", n).unwrap(); // idempotent
        let mut all = vec![0u8; n];
        eng.read("t", &mut all).unwrap(); // fresh zeros verify
        assert!(all.iter().all(|&b| b == 0));
        // unaligned tile writes spanning block edges
        let want: Vec<u8> = (0..n).map(|i| (i * 7 % 253) as u8).collect();
        let tile = 100_003usize;
        let mut off = 0;
        while off < n {
            let len = tile.min(n - off);
            eng.write_at("t", off, &want[off..off + len]).unwrap();
            off += len;
        }
        eng.flush("t").unwrap();
        let mut out = vec![0u8; n];
        eng.read("t", &mut out).unwrap();
        assert_eq!(out, want);
        assert_eq!(eng.failures(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn any_single_bit_flip_is_detected_and_clean_replays_never_flag() {
        let dir = tmpdir("prop");
        // persisted stream families the trainer actually writes
        let families = ["master/w0", "optim/sg0/fp16", "adam_m/g1", "journal/slot0"];
        check("integrity-bitflip", Config { cases: 16, ..Default::default() }, |rng, size| {
            let case = dir.join(format!("c{}", rng.next_u64()));
            std::fs::create_dir_all(&case).map_err(|e| e.to_string())?;
            let base = direct(&case);
            let eng = IntegrityEngine::new(base.clone());
            let key = families[rng.below(families.len())];
            let n = rng.range(1, (size.max(2) * 128).min(3 * BLOCK_BYTES));
            let data: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
            eng.write(key, &data).map_err(|e| e.to_string())?;
            // clean replay: no false positive
            let mut out = vec![0u8; n];
            eng.read(key, &mut out).map_err(|e| e.to_string())?;
            prop_assert!(out == data, "clean read diverged");
            prop_assert!(eng.failures() == 0, "false positive on clean replay");
            // flip one random bit *below* the integrity layer
            let byte = rng.below(n);
            let bit = rng.below(8) as u8;
            base.write_at(key, byte, &[data[byte] ^ (1 << bit)])
                .map_err(|e| e.to_string())?;
            let err = match eng.read(key, &mut out) {
                Ok(()) => return Err("bit flip not detected".into()),
                Err(e) => e,
            };
            let ie = err
                .downcast_ref::<IntegrityError>()
                .ok_or("mismatch was not a typed IntegrityError")?;
            prop_assert!(ie.key == key, "wrong key in error");
            prop_assert!(ie.block == byte / BLOCK_BYTES, "wrong block in error");
            // a ranged read over the flipped byte detects it too
            let mut one = [0u8; 1];
            prop_assert!(
                eng.read_at(key, byte, &mut one).is_err(),
                "ranged read missed the flip"
            );
            // healing the bit heals the read: detection has no memory
            base.write_at(key, byte, &[data[byte]]).map_err(|e| e.to_string())?;
            eng.read(key, &mut out).map_err(|e| e.to_string())?;
            prop_assert!(out == data, "healed read diverged");
            std::fs::remove_dir_all(&case).ok();
            Ok(())
        });
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Inner engine that corrupts the first `n` reads in the out
    /// buffer — transient misreads, durable bytes intact.
    struct MisreadEngine {
        inner: Arc<dyn NvmeEngine>,
        left: AtomicU64,
    }

    impl NvmeEngine for MisreadEngine {
        fn write(&self, key: &str, data: &[u8]) -> anyhow::Result<()> {
            self.inner.write(key, data)
        }
        fn read(&self, key: &str, out: &mut [u8]) -> anyhow::Result<()> {
            self.inner.read(key, out)?;
            if !is_sidecar(key)
                && self
                    .left
                    .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1))
                    .is_ok()
            {
                out[0] ^= 0x80;
            }
            Ok(())
        }
        fn write_at(&self, key: &str, offset: usize, data: &[u8]) -> anyhow::Result<()> {
            self.inner.write_at(key, offset, data)
        }
        fn len_of(&self, key: &str) -> Option<usize> {
            self.inner.len_of(key)
        }
        fn stats(&self) -> IoSnapshot {
            self.inner.stats()
        }
        fn label(&self) -> &'static str {
            self.inner.label()
        }
    }

    #[test]
    fn transient_misreads_heal_by_retry_durable_rot_exhausts_typed() {
        let dir = tmpdir("retry");
        let base = direct(&dir);
        let misread =
            Arc::new(MisreadEngine { inner: base.clone(), left: AtomicU64::new(2) });
        let integ = Arc::new(IntegrityEngine::new(misread));
        let eng = RetryEngine::new(integ.clone(), RetryPolicy::attempts(4));
        let data: Vec<u8> = (0..9000).map(|i| (i % 201) as u8).collect();
        eng.write("k", &data).unwrap();
        // two transient misreads absorbed; bytes come back clean
        let mut out = vec![0u8; data.len()];
        eng.read("k", &mut out).unwrap();
        assert_eq!(out, data);
        assert!(eng.retries() >= 2, "retries not metered: {}", eng.retries());
        assert_eq!(integ.failures(), 2);
        // durable rot: every re-read fails, budget exhausts, and the
        // typed mismatch is preserved in the exhaustion error text
        base.write_at("k", 17, &[data[17] ^ 1]).unwrap();
        let err = eng.read("k", &mut out).unwrap_err();
        let ex = err.downcast_ref::<crate::ssd::RetryExhausted>().expect("typed exhaustion");
        assert!(ex.last.contains("integrity mismatch"), "lost cause: {}", ex.last);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn violations_are_metered_and_emitted_as_events() {
        let dir = tmpdir("ev");
        let base = direct(&dir);
        let eng = IntegrityEngine::new(base.clone()).for_job(JobId(3));
        let sink = MemorySink::new();
        eng.set_sink(sink.clone());
        eng.write("k", &[7u8; 4096]).unwrap();
        base.write_at("k", 100, &[0x55]).unwrap();
        let mut out = vec![0u8; 4096];
        assert!(eng.read("k", &mut out).is_err());
        assert!(eng.scrub("k").is_err());
        assert_eq!(eng.failures(), 2);
        assert_eq!(eng.scrub_failures(), 1);
        let evs = sink.for_job(JobId(3));
        assert_eq!(evs.len(), 2);
        assert!(matches!(
            &evs[0].kind,
            EventKind::IntegrityViolation { key, block: 0 } if key == "k"
        ));
        let s = eng.stats();
        assert_eq!(s.integrity_failures, 2);
        assert_eq!(s.scrub_failures, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn concurrent_disjoint_tiles_do_not_interfere() {
        let dir = tmpdir("conc");
        let eng = Arc::new(IntegrityEngine::new(direct(&dir)));
        let n = 4 * BLOCK_BYTES;
        eng.reserve("t", n).unwrap();
        let mut rng = Xoshiro256::new(0xC0FFEE);
        let want: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        std::thread::scope(|s| {
            for t in 0..4 {
                let eng = Arc::clone(&eng);
                let want = &want;
                s.spawn(move || {
                    let off = t * BLOCK_BYTES;
                    eng.write_at("t", off, &want[off..off + BLOCK_BYTES]).unwrap();
                });
            }
        });
        let mut out = vec![0u8; n];
        eng.read("t", &mut out).unwrap();
        assert_eq!(out, want);
        assert_eq!(eng.failures(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
