//! SSD tier: storage engines + analytic device model.
//!
//! The offload engine reads/writes four tensor families per iteration
//! (fp16 compute weights, fp32 masters, optimizer momentum/variance) —
//! all "hot at every step" (paper §II-A).  Two interchangeable engines:
//!
//! - [`fs_engine::FsEngine`] — the DeepNVMe-style baseline: one file
//!   per tensor on a filesystem, software-RAID0 striping across
//!   devices, paying path resolution / metadata / allocation costs on
//!   every transfer (§III-D).
//! - [`direct::DirectEngine`] — MemAscend's direct NVMe engine (§IV-E):
//!   devices are raw LBA spaces (flat preallocated files standing in
//!   for `/dev/nvme*n1`), a location allocator hands out aligned
//!   extents exactly once per tensor, a tensor-location dictionary maps
//!   keys to (device, lba, len) stripes, and worker threads fan
//!   requests across devices.
//!
//! [`device_model::DeviceModel`] supplies the *device physics* (queue
//! latency, SLC-cache destaging) that container-backed files cannot
//! exhibit, for full-scale projections (Fig. 14's curve shapes).
//!
//! [`queue`] is the async multi-queue layer both engines sit on: a
//! submission/completion-queue executor with persistent per-device
//! worker pools, plus [`queue::AsyncEngine`] — the `submit_read` /
//! `submit_write` surface the swapper pipeline and the double-buffered
//! optimizer swap are built from.

pub mod device_model;
pub mod faulty;
pub mod direct;
pub mod fs_engine;
pub mod queue;

pub use device_model::DeviceModel;
pub use faulty::FaultyEngine;
pub use direct::DirectEngine;
pub use fs_engine::FsEngine;
pub use queue::{io_scope, AsyncEngine, IoExecutor, IoHandle, IoScope};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Engine-busy interval tracking: the union of all in-flight transfer
/// windows.  Per-call elapsed sums double-count when the queue layer
/// runs transfers concurrently (two overlapping 10 ms reads are 10 ms
/// of device-busy wall time, not 20 ms); the epoch counter here closes
/// a busy window only when the *last* in-flight call finishes, so
/// `busy_ns` is the exact union and overlap metrics built on it are
/// exact too (ROADMAP item, resolved).
#[derive(Debug, Default)]
struct BusyState {
    active: u32,
    epoch: Option<Instant>,
    busy_ns: u64,
}

/// I/O statistics common to both engines.
#[derive(Debug, Default)]
pub struct IoStats {
    pub reads: AtomicU64,
    pub writes: AtomicU64,
    pub bytes_read: AtomicU64,
    pub bytes_written: AtomicU64,
    /// Nanoseconds spent inside engine calls, summed per call (feeds
    /// bandwidth figures; can exceed wall time under concurrency).
    pub read_ns: AtomicU64,
    pub write_ns: AtomicU64,
    busy: Mutex<BusyState>,
}

/// RAII marker for one in-flight engine call; closing the last one
/// closes the busy window.
pub struct BusyGuard<'a> {
    stats: &'a IoStats,
}

impl Drop for BusyGuard<'_> {
    fn drop(&mut self) {
        let mut b = self.stats.busy.lock().unwrap();
        b.active -= 1;
        if b.active == 0 {
            if let Some(t0) = b.epoch.take() {
                b.busy_ns += t0.elapsed().as_nanos() as u64;
            }
        }
    }
}

impl IoStats {
    /// Mark one transfer in flight for the guard's lifetime.
    pub fn busy_guard(&self) -> BusyGuard<'_> {
        let mut b = self.busy.lock().unwrap();
        if b.active == 0 {
            b.epoch = Some(Instant::now());
        }
        b.active += 1;
        drop(b);
        BusyGuard { stats: self }
    }

    pub fn record_read(&self, bytes: u64, ns: u64) {
        self.reads.fetch_add(1, Ordering::Relaxed);
        self.bytes_read.fetch_add(bytes, Ordering::Relaxed);
        self.read_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn record_write(&self, bytes: u64, ns: u64) {
        self.writes.fetch_add(1, Ordering::Relaxed);
        self.bytes_written.fetch_add(bytes, Ordering::Relaxed);
        self.write_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> IoSnapshot {
        let busy_ns = {
            let b = self.busy.lock().unwrap();
            // include the open window so deltas taken mid-flight are
            // still monotone and exact
            b.busy_ns
                + b.epoch
                    .map(|t0| t0.elapsed().as_nanos() as u64)
                    .unwrap_or(0)
        };
        IoSnapshot {
            reads: self.reads.load(Ordering::Relaxed),
            writes: self.writes.load(Ordering::Relaxed),
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
            read_ns: self.read_ns.load(Ordering::Relaxed),
            write_ns: self.write_ns.load(Ordering::Relaxed),
            busy_ns,
        }
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct IoSnapshot {
    pub reads: u64,
    pub writes: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub read_ns: u64,
    pub write_ns: u64,
    /// Union-of-intervals engine-busy time (never exceeds wall time).
    pub busy_ns: u64,
}

impl IoSnapshot {
    pub fn read_bw(&self) -> f64 {
        if self.read_ns == 0 {
            return 0.0;
        }
        self.bytes_read as f64 / (self.read_ns as f64 / 1e9)
    }

    pub fn write_bw(&self) -> f64 {
        if self.write_ns == 0 {
            return 0.0;
        }
        self.bytes_written as f64 / (self.write_ns as f64 / 1e9)
    }

    pub fn busy_secs(&self) -> f64 {
        self.busy_ns as f64 / 1e9
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_read + self.bytes_written
    }
}

/// The interface the swapper / optimizer drive. Implementations must be
/// safe to call from multiple worker threads.
pub trait NvmeEngine: Send + Sync {
    /// Write `data` under `key`, overwriting any previous contents.
    fn write(&self, key: &str, data: &[u8]) -> anyhow::Result<()>;

    /// Read the full value of `key` into `out` (must match stored len).
    fn read(&self, key: &str, out: &mut [u8]) -> anyhow::Result<()>;

    /// Stored length of `key`, if present.
    fn len_of(&self, key: &str) -> Option<usize>;

    fn stats(&self) -> IoSnapshot;

    fn label(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::{check, Config};

    fn engines(dir: &std::path::Path) -> Vec<Box<dyn NvmeEngine>> {
        vec![
            Box::new(FsEngine::new(&dir.join("fs"), 2, 1 << 20).unwrap()),
            Box::new(DirectEngine::new(&dir.join("direct"), 2, 1 << 24, 1).unwrap()),
        ]
    }

    #[test]
    fn busy_time_is_union_of_overlapping_intervals() {
        let stats = std::sync::Arc::new(IoStats::default());
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let stats = stats.clone();
                s.spawn(move || {
                    // 4 fully-overlapping 60 ms "transfers"
                    let _busy = stats.busy_guard();
                    stats.record_read(1, 60_000_000);
                    std::thread::sleep(std::time::Duration::from_millis(60));
                });
            }
        });
        let wall = t0.elapsed().as_nanos() as u64;
        let snap = stats.snapshot();
        // per-call sum double-counts (4 × 60 ms)…
        assert_eq!(snap.read_ns, 240_000_000);
        // …while the busy union is bounded by wall time and covers at
        // least one transfer's span
        assert!(snap.busy_ns <= wall, "busy {} > wall {}", snap.busy_ns, wall);
        assert!(snap.busy_ns >= 55_000_000, "busy {} too small", snap.busy_ns);
    }

    #[test]
    fn engine_busy_never_exceeds_per_call_sum() {
        let tmp = std::env::temp_dir().join(format!("ma-busy-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        let eng = DirectEngine::new(&tmp, 2, 1 << 24, 2).unwrap();
        for i in 0..8 {
            eng.write(&format!("k{i}"), &vec![i as u8; 100_000]).unwrap();
        }
        let s = eng.stats();
        assert!(s.busy_ns > 0);
        assert!(s.busy_ns <= s.read_ns + s.write_ns);
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn prop_write_read_roundtrip_both_engines() {
        let tmp = std::env::temp_dir().join(format!("ma-ssd-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        check("ssd-roundtrip", Config { cases: 24, ..Default::default() }, |rng, size| {
            let dir = tmp.join(format!("c{}", rng.next_u64()));
            for eng in engines(&dir) {
                let mut store: std::collections::HashMap<String, Vec<u8>> =
                    Default::default();
                for i in 0..rng.range(1, 8) {
                    // tensor sizes are fixed for a training run: reuse
                    // of a key always carries the same length (the
                    // direct engine's extents are immutable by design)
                    let key_id = rng.below(4);
                    let key = format!("t{key_id}");
                    let n = match store.get(&key) {
                        Some(prev) => prev.len(),
                        None => rng.range(1, size.max(2) * 16),
                    };
                    let data: Vec<u8> =
                        (0..n).map(|j| ((i * 31 + j * 7) % 256) as u8).collect();
                    eng.write(&key, &data).map_err(|e| e.to_string())?;
                    store.insert(key, data);
                }
                for (key, want) in &store {
                    let mut out = vec![0u8; want.len()];
                    eng.read(key, &mut out).map_err(|e| e.to_string())?;
                    prop_assert!(
                        &out == want,
                        "{}: key {key} corrupted ({} bytes)",
                        eng.label(),
                        want.len()
                    );
                    prop_assert!(
                        eng.len_of(key) == Some(want.len()),
                        "len_of mismatch"
                    );
                }
            }
            std::fs::remove_dir_all(&dir).ok();
            Ok(())
        });
        std::fs::remove_dir_all(&tmp).ok();
    }
}
