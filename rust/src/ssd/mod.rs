//! SSD tier: storage engines + analytic device model.
//!
//! The offload engine reads/writes four tensor families per iteration
//! (fp16 compute weights, fp32 masters, optimizer momentum/variance) —
//! all "hot at every step" (paper §II-A).  Two interchangeable engines:
//!
//! - [`fs_engine::FsEngine`] — the DeepNVMe-style baseline: one file
//!   per tensor on a filesystem, software-RAID0 striping across
//!   devices, paying path resolution / metadata / allocation costs on
//!   every transfer (§III-D).
//! - [`direct::DirectEngine`] — MemAscend's direct NVMe engine (§IV-E):
//!   devices are raw LBA spaces (flat preallocated files standing in
//!   for `/dev/nvme*n1`), a location allocator hands out aligned
//!   extents exactly once per tensor, a tensor-location dictionary maps
//!   keys to (device, lba, len) stripes, and worker threads fan
//!   requests across devices.
//!
//! [`device_model::DeviceModel`] supplies the *device physics* (queue
//! latency, SLC-cache destaging) that container-backed files cannot
//! exhibit, for full-scale projections (Fig. 14's curve shapes).
//!
//! [`queue`] is the async multi-queue layer both engines sit on: a
//! submission/completion-queue executor with persistent per-device
//! worker pools, plus [`queue::AsyncEngine`] — the `submit_read` /
//! `submit_write` surface the swapper pipeline and the double-buffered
//! optimizer swap are built from.
//!
//! ## Durability contract
//!
//! The write path is deliberately two-phase so the training loop pays
//! no per-step durability tax:
//!
//! - `write`/`write_at` make data *visible* (a subsequent read on any
//!   thread returns the new bytes) but not necessarily *durable*: the
//!   tiled optimizer's ranged writes never fsync per tile.
//! - [`NvmeEngine::flush`] is the explicit per-key durability barrier.
//!   What it guarantees per engine:
//!   - [`FsEngine`]: `fdatasync` on every RAID member file of the key —
//!     after `flush(k)` returns, `k`'s bytes survive a crash.
//!   - [`DirectEngine`]: `fdatasync` on every device file holding one
//!     of `k`'s extents, after verifying the key's location-dictionary
//!     entry is persisted (the dictionary itself is journaled to a
//!     sidecar at allocation time, off the data path, so a reopened
//!     engine can find every tensor again).
//!   - [`queue::AsyncEngine`]: delegates to the wrapped engine, after
//!     the caller has drained its in-flight submissions for the key.
//!
//!   The PR-3 caveat ("buffered ranged writes reach a defined durable
//!   state only at drain") is thereby resolved into a contract: the
//!   checkpoint path ([`crate::ckpt`]) issues per-key `flush` barriers
//!   and then commits an epoch journal, so a crash rolls back to the
//!   last committed epoch instead of losing the run.
//!
//! ## The robustness decorator stack
//!
//! Every layer above the base engines is an [`NvmeEngine`] decorator,
//! and the *order* they compose in is a contract, not a convenience.
//! The full per-job stack the offload engine assembles is
//!
//! ```text
//! Shadow( Retry( Integrity( Faulty?( Scoped( base )))))
//! ```
//!
//! - [`integrity::IntegrityEngine`] checksums every write (FNV-1a per
//!   256 KiB block, sidecar `sums/{key}`) and verifies every read,
//!   surfacing mismatches as typed [`integrity::IntegrityError`]s and
//!   metering them in [`IoSnapshot::integrity_failures`].
//! - [`retry::RetryEngine`] wraps any engine with bounded,
//!   exponential-backoff retry ([`retry::RetryPolicy`]), metered in
//!   [`IoSnapshot::retries`] / [`IoSnapshot::retry_exhaustions`] and
//!   attributed per tenant via [`IoSnapshot::job_retries`].
//! - [`FaultyEngine`] provides the deterministic fault injection the
//!   retry/recovery/chaos tests are built on: probabilistic or
//!   transient errors, latency spikes, and bit-flip corruption, each
//!   gated by per-op-kind masks.
//! - `ScopedEngine` (in [`crate::jobs`]) prefixes keys with a job
//!   namespace; `ShadowEngine` (in [`crate::ckpt`]) multiplexes keys
//!   across checkpoint shadow extents.
//!
//! Why this order and no other:
//!
//! - **Integrity sits *below* Retry** so a checksum mismatch is
//!   retryable: a transient misread heals on re-read, while durable
//!   rot exhausts the budget and surfaces a typed
//!   [`retry::RetryExhausted`] whose last-error text preserves the
//!   `IntegrityError` — the caller aborts rather than training on
//!   corrupt bytes.
//! - **Integrity sits *above* Faulty** so injected write-path
//!   corruption lands *under* the checksums and is caught, which is
//!   exactly what the chaos tests assert.
//! - **Integrity sits *above* Scoped** so the `sums/{key}` sidecar
//!   rides the same job prefix as its data and tenants can't collide.
//! - **Shadow sits on top** so each physical shadow extent carries its
//!   own sums; a rolled-back epoch verifies against the sums written
//!   with it.
//!
//! [`queue::AsyncEngine`] fronts the whole stack with the shared
//! submission pool; every async fetch/write-back therefore inherits
//! verification and retry with no extra plumbing.  Its
//! [`queue::IoExecutor`] carries a [`health::HealthTracker`] — latency
//! EWMA/p99, error and timeout meters, and a quarantine state machine
//! that emits typed `DeviceDegraded`/`DeviceRecovered` events for the
//! governors.  With a per-op deadline configured
//! (`TrainSpec::io_deadline_ms`), stalled owned-buffer reads are
//! *hedged*: re-submitted on the same queue after the rolling p99,
//! first completion wins.

pub mod device_model;
pub mod faulty;
pub mod direct;
pub mod fs_engine;
pub mod health;
pub mod integrity;
pub mod queue;
pub mod retry;
pub mod sched;

pub use device_model::DeviceModel;
pub use faulty::{FaultyEngine, OpKind, OpMask};
pub use direct::DirectEngine;
pub use fs_engine::FsEngine;
pub use health::{HealthConfig, HealthTracker};
pub use integrity::{IntegrityEngine, IntegrityError, BLOCK_BYTES};
pub use queue::{io_scope, AsyncEngine, IoExecutor, IoHandle, IoScope};
pub use retry::{RetryEngine, RetryExhausted, RetryPolicy};
pub use sched::DwrrQueue;

pub use crate::util::events::{JobId, MAX_JOB_LANES};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Engine-busy interval tracking: the union of all in-flight transfer
/// windows.  Per-call elapsed sums double-count when the queue layer
/// runs transfers concurrently (two overlapping 10 ms reads are 10 ms
/// of device-busy wall time, not 20 ms); the epoch counter here closes
/// a busy window only when the *last* in-flight call finishes, so
/// `busy_ns` is the exact union and overlap metrics built on it are
/// exact too (ROADMAP item, resolved).
#[derive(Debug, Default)]
struct BusyState {
    active: u32,
    epoch: Option<Instant>,
    busy_ns: u64,
    /// Whether this window was ever opened (distinguishes an idle
    /// per-queue slot from one whose transfers were just very short).
    used: bool,
}

impl BusyState {
    fn open(&mut self) {
        if self.active == 0 {
            self.epoch = Some(Instant::now());
        }
        self.active += 1;
        self.used = true;
    }

    fn close(&mut self) {
        self.active -= 1;
        if self.active == 0 {
            if let Some(t0) = self.epoch.take() {
                self.busy_ns += t0.elapsed().as_nanos() as u64;
            }
        }
    }

    /// Busy union including the currently-open window, so deltas taken
    /// mid-flight are still monotone and exact.
    fn total_ns(&self) -> u64 {
        self.busy_ns
            + self
                .epoch
                .map(|t0| t0.elapsed().as_nanos() as u64)
                .unwrap_or(0)
    }
}

/// Most per-queue busy slots a snapshot carries (keeps [`IoSnapshot`]
/// `Copy`); engines here run 2-3 device queues.
pub const MAX_QUEUES: usize = 8;

/// I/O statistics common to both engines.
#[derive(Debug, Default)]
pub struct IoStats {
    pub reads: AtomicU64,
    pub writes: AtomicU64,
    pub bytes_read: AtomicU64,
    pub bytes_written: AtomicU64,
    /// Nanoseconds spent inside engine calls, summed per call (feeds
    /// bandwidth figures; can exceed wall time under concurrency).
    pub read_ns: AtomicU64,
    pub write_ns: AtomicU64,
    busy: Mutex<BusyState>,
    /// Per-queue (per NVMe device / RAID member) busy unions, indexed
    /// by the queue id the engine hands to [`IoStats::queue_guard`].
    /// One lock *per queue*: jobs on independent device queues never
    /// contend here (the whole point of the multi-queue layer).
    queues: [Mutex<BusyState>; MAX_QUEUES],
}

/// RAII marker for one in-flight engine call; closing the last one
/// closes the busy window.
pub struct BusyGuard<'a> {
    stats: &'a IoStats,
}

impl Drop for BusyGuard<'_> {
    fn drop(&mut self) {
        self.stats.busy.lock().unwrap().close();
    }
}

/// RAII marker for one in-flight transfer on a specific device queue;
/// the per-queue analog of [`BusyGuard`], so overlap wins can be
/// attributed to individual NVMe queues.
pub struct QueueBusyGuard<'a> {
    stats: &'a IoStats,
    queue: usize,
}

impl Drop for QueueBusyGuard<'_> {
    fn drop(&mut self) {
        if let Some(q) = self.stats.queues.get(self.queue) {
            q.lock().unwrap().close();
        }
    }
}

impl IoStats {
    /// Mark one transfer in flight for the guard's lifetime.
    pub fn busy_guard(&self) -> BusyGuard<'_> {
        self.busy.lock().unwrap().open();
        BusyGuard { stats: self }
    }

    /// Mark one transfer in flight on device queue `queue`.  Queues
    /// past [`MAX_QUEUES`] are still unioned into the engine-wide
    /// window by the caller's [`Self::busy_guard`], just not broken
    /// out per queue.
    pub fn queue_guard(&self, queue: usize) -> QueueBusyGuard<'_> {
        if let Some(q) = self.queues.get(queue) {
            q.lock().unwrap().open();
        }
        QueueBusyGuard { stats: self, queue }
    }

    pub fn record_read(&self, bytes: u64, ns: u64) {
        self.reads.fetch_add(1, Ordering::Relaxed);
        self.bytes_read.fetch_add(bytes, Ordering::Relaxed);
        self.read_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn record_write(&self, bytes: u64, ns: u64) {
        self.writes.fetch_add(1, Ordering::Relaxed);
        self.bytes_written.fetch_add(bytes, Ordering::Relaxed);
        self.write_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> IoSnapshot {
        let busy_ns = self.busy.lock().unwrap().total_ns();
        let mut queue_busy_ns = [0u64; MAX_QUEUES];
        let mut queue_count = 0;
        for (i, q) in self.queues.iter().enumerate() {
            let b = q.lock().unwrap();
            if b.used {
                queue_busy_ns[i] = b.total_ns();
                queue_count = i + 1;
            }
        }
        IoSnapshot {
            reads: self.reads.load(Ordering::Relaxed),
            writes: self.writes.load(Ordering::Relaxed),
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
            read_ns: self.read_ns.load(Ordering::Relaxed),
            write_ns: self.write_ns.load(Ordering::Relaxed),
            busy_ns,
            queue_busy_ns,
            queue_count,
            retries: 0,
            retry_exhaustions: 0,
            // per-job lanes are queue-service accounting: the shared
            // IoExecutor overlays them (AsyncEngine::stats), the same
            // way RetryEngine overlays the retry counters
            ..Default::default()
        }
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct IoSnapshot {
    pub reads: u64,
    pub writes: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub read_ns: u64,
    pub write_ns: u64,
    /// Union-of-intervals engine-busy time (never exceeds wall time).
    pub busy_ns: u64,
    /// Per-queue busy unions (device `q` of the direct engine, RAID
    /// member `q` of the fs engine); slots `>= queue_count` are zero.
    pub queue_busy_ns: [u64; MAX_QUEUES],
    /// Queues that ever went busy (`<= MAX_QUEUES`).
    pub queue_count: usize,
    /// Transient-fault retries performed by a [`RetryEngine`] layered
    /// over this engine (0 when no retry layer is present).  A
    /// non-zero count with a successful op means the backoff absorbed
    /// a transient fault; exhausted retries still surface as `Err`.
    pub retries: u64,
    /// Ops whose whole retry budget failed ([`retry::RetryExhausted`]
    /// surfaced to the caller) — metered apart from [`Self::retries`]
    /// so absorbed blips and terminal failures never blur together.
    pub retry_exhaustions: u64,
    /// Per-job queue service: tasks dispatched on each job lane by the
    /// shared [`IoExecutor`] (0 when no executor overlays this
    /// snapshot; see [`AsyncEngine::stats`]).  Lane assignment is
    /// [`JobId::lane`]; [`JobId::HOST`] is lane 0.
    pub job_ops: [u64; MAX_JOB_LANES],
    /// Per-job scheduled cost (bytes for transfers, 1 per control op)
    /// dispatched on each lane — the weighted-fair scheduler's
    /// currency, so lane ratios here are what the weights shape.
    pub job_bytes: [u64; MAX_JOB_LANES],
    /// Per-job wall-clock worker occupancy (queue service time): how
    /// long the pool's workers spent executing each job's submissions.
    pub job_busy_ns: [u64; MAX_JOB_LANES],
    /// Per-job retry counts: the [`RetryEngine`] lane view set by
    /// [`RetryEngine::for_job`], so fault absorption attributes to
    /// tenants the same way ops/bytes do.
    pub job_retries: [u64; MAX_JOB_LANES],
    /// Per-job retry exhaustions (terminal failures per tenant).
    pub job_retry_exhaustions: [u64; MAX_JOB_LANES],
    /// Checksum mismatches detected by an [`IntegrityEngine`] layered
    /// over this engine (0 without one).  Each is also surfaced as a
    /// typed [`IntegrityError`] to the caller and, when a sink is
    /// wired, an `IntegrityViolation` event.
    pub integrity_failures: u64,
    /// Bytes verified by the background scrubber between steps.
    pub scrubbed_bytes: u64,
    /// Scrub passes that failed verification (each also counts in
    /// [`Self::integrity_failures`]).
    pub scrub_failures: u64,
}

impl IoSnapshot {
    pub fn read_bw(&self) -> f64 {
        if self.read_ns == 0 {
            return 0.0;
        }
        self.bytes_read as f64 / (self.read_ns as f64 / 1e9)
    }

    pub fn write_bw(&self) -> f64 {
        if self.write_ns == 0 {
            return 0.0;
        }
        self.bytes_written as f64 / (self.write_ns as f64 / 1e9)
    }

    pub fn busy_secs(&self) -> f64 {
        self.busy_ns as f64 / 1e9
    }

    /// Busy union of one device queue in seconds (0 for unused slots).
    pub fn queue_busy_secs(&self, queue: usize) -> f64 {
        if queue >= MAX_QUEUES {
            return 0.0;
        }
        self.queue_busy_ns[queue] as f64 / 1e9
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_read + self.bytes_written
    }

    /// Total NVMe submissions (read + write calls reaching the
    /// engine).  Deltas of this counter are the per-step submission
    /// count the optimizer's group-coalescing pass exists to reduce:
    /// many small per-tensor transfers and few long ranged ones move
    /// the same bytes but very different submission counts.
    pub fn ops(&self) -> u64 {
        self.reads + self.writes
    }

    /// One job's queue service time in seconds (0 for unused lanes).
    pub fn job_busy_secs(&self, job: JobId) -> f64 {
        self.job_busy_ns[job.lane()] as f64 / 1e9
    }

    /// One job's share of total scheduled cost across all lanes
    /// (0.0 when nothing was dispatched) — the quantity the DWRR
    /// weights shape, and what `bench_tenancy` gates on.
    pub fn job_share(&self, job: JobId) -> f64 {
        let total: u64 = self.job_bytes.iter().sum();
        if total == 0 {
            return 0.0;
        }
        self.job_bytes[job.lane()] as f64 / total as f64
    }
}

/// The interface the swapper / optimizer drive. Implementations must be
/// safe to call from multiple worker threads.
///
/// The ranged surface (`read_at`/`write_at`/`reserve`) exists for the
/// tiled optimizer pipeline: a tensor's value is fixed-length once
/// written (or reserved), and tiles address disjoint byte windows of it
/// concurrently — **concurrent `read_at`/`write_at` calls on disjoint
/// ranges of one key must not interfere**.  `read_at`/`reserve` have
/// whole-value defaults that honour that contract (reads don't
/// interfere; reserve is a one-time full write); `write_at` is a
/// *required* method precisely because the obvious whole-value
/// read-modify-write default would lose concurrent disjoint updates.
pub trait NvmeEngine: Send + Sync {
    /// Write `data` under `key`, overwriting any previous contents.
    fn write(&self, key: &str, data: &[u8]) -> anyhow::Result<()>;

    /// Read the full value of `key` into `out` (must match stored len).
    fn read(&self, key: &str, out: &mut [u8]) -> anyhow::Result<()>;

    /// Read `out.len()` bytes of `key`'s value starting at byte
    /// `offset`.
    fn read_at(&self, key: &str, offset: usize, out: &mut [u8]) -> anyhow::Result<()> {
        let stored = self
            .len_of(key)
            .ok_or_else(|| anyhow::anyhow!("{}: no tensor '{key}'", self.label()))?;
        anyhow::ensure!(
            offset + out.len() <= stored,
            "{}: ranged read past '{key}' ({offset}+{} > {stored})",
            self.label(),
            out.len()
        );
        let mut tmp = vec![0u8; stored];
        self.read(key, &mut tmp)?;
        out.copy_from_slice(&tmp[offset..offset + out.len()]);
        Ok(())
    }

    /// Write `data` into `key`'s value at byte `offset`, leaving the
    /// stored length unchanged.  The key must already exist (write the
    /// full value once, or [`Self::reserve`] it).  Implementations
    /// must patch the addressed bytes in place — never read-modify-
    /// write the whole value, which would clobber concurrent disjoint
    /// tiles.
    fn write_at(&self, key: &str, offset: usize, data: &[u8]) -> anyhow::Result<()>;

    /// Make `key`'s stored bytes durable (the fsync analog) — the
    /// per-key barrier the checkpoint journal's epoch commit is built
    /// on (see the module-level durability contract).  `write_at`
    /// never syncs per tile; callers that need a durability point
    /// (the [`crate::ckpt`] commit path, `Trainer::drain`) call this
    /// once per key after their buffered/ranged writes.  Flushing an
    /// absent key is a no-op, so barriers can sweep optional keys.
    /// Default is a no-op — only correct for engines whose writes are
    /// already durable on return; both real engines override it.
    fn flush(&self, _key: &str) -> anyhow::Result<()> {
        Ok(())
    }

    /// Ensure `key` exists with exactly `len` stored bytes so ranged
    /// writes can target it — allocating storage without moving data
    /// where the engine supports it (fresh contents are zero).
    fn reserve(&self, key: &str, len: usize) -> anyhow::Result<()> {
        match self.len_of(key) {
            Some(stored) => {
                anyhow::ensure!(
                    stored == len,
                    "{}: reserve size change for '{key}' ({stored} -> {len}) unsupported",
                    self.label()
                );
                Ok(())
            }
            None => self.write(key, &vec![0u8; len]),
        }
    }

    /// Stored length of `key`, if present.
    fn len_of(&self, key: &str) -> Option<usize>;

    fn stats(&self) -> IoSnapshot;

    fn label(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::{check, Config};

    fn engines(dir: &std::path::Path) -> Vec<Box<dyn NvmeEngine>> {
        vec![
            Box::new(FsEngine::new(&dir.join("fs"), 2, 1 << 20).unwrap()),
            Box::new(DirectEngine::new(&dir.join("direct"), 2, 1 << 24, 1).unwrap()),
        ]
    }

    #[test]
    fn busy_time_is_union_of_overlapping_intervals() {
        let stats = std::sync::Arc::new(IoStats::default());
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let stats = stats.clone();
                s.spawn(move || {
                    // 4 fully-overlapping 60 ms "transfers"
                    let _busy = stats.busy_guard();
                    stats.record_read(1, 60_000_000);
                    std::thread::sleep(std::time::Duration::from_millis(60));
                });
            }
        });
        let wall = t0.elapsed().as_nanos() as u64;
        let snap = stats.snapshot();
        // per-call sum double-counts (4 × 60 ms)…
        assert_eq!(snap.read_ns, 240_000_000);
        // …while the busy union is bounded by wall time and covers at
        // least one transfer's span
        assert!(snap.busy_ns <= wall, "busy {} > wall {}", snap.busy_ns, wall);
        assert!(snap.busy_ns >= 55_000_000, "busy {} too small", snap.busy_ns);
    }

    #[test]
    fn engine_busy_never_exceeds_per_call_sum() {
        let tmp = std::env::temp_dir().join(format!("ma-busy-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        let eng = DirectEngine::new(&tmp, 2, 1 << 24, 2).unwrap();
        for i in 0..8 {
            eng.write(&format!("k{i}"), &vec![i as u8; 100_000]).unwrap();
        }
        let s = eng.stats();
        assert!(s.busy_ns > 0);
        assert!(s.busy_ns <= s.read_ns + s.write_ns);
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn ranged_io_roundtrips_on_both_engines() {
        let tmp = std::env::temp_dir().join(format!("ma-ssdrg-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        for eng in engines(&tmp) {
            // reserve-then-tile: the tiled optimizer's write pattern
            let n = 40_000usize;
            eng.reserve("t", n).unwrap();
            assert_eq!(eng.len_of("t"), Some(n));
            eng.reserve("t", n).unwrap(); // idempotent
            assert!(eng.reserve("t", n + 1).is_err(), "size change must error");
            let want: Vec<u8> = (0..n).map(|i| (i % 251) as u8).collect();
            // non-aligned tile windows covering the whole value
            let tile = 7177usize;
            let mut off = 0;
            while off < n {
                let len = tile.min(n - off);
                eng.write_at("t", off, &want[off..off + len]).unwrap();
                off += len;
            }
            // one durability point per key after the tile writes
            eng.flush("t").unwrap();
            eng.flush("absent-key").unwrap(); // flush of nothing is a no-op
            let mut full = vec![0u8; n];
            eng.read("t", &mut full).unwrap();
            assert_eq!(full, want, "{}: tiled writes diverged", eng.label());
            // ranged reads at awkward offsets, including spans that
            // cross stripe/extent boundaries
            for (off, len) in [(0usize, 1usize), (4095, 2), (12_288, 9000), (n - 3, 3)] {
                let mut out = vec![0u8; len];
                eng.read_at("t", off, &mut out).unwrap();
                assert_eq!(out, &want[off..off + len], "{} @{off}+{len}", eng.label());
            }
            // out-of-bounds and missing keys surface as errors
            let mut out = vec![0u8; 8];
            assert!(eng.read_at("t", n - 4, &mut out).is_err());
            assert!(eng.write_at("t", n - 4, &[0u8; 8]).is_err());
            assert!(eng.read_at("missing", 0, &mut out).is_err());
            assert!(eng.write_at("missing", 0, &[0u8; 8]).is_err());
        }
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn per_queue_busy_tracks_each_device() {
        let stats = IoStats::default();
        {
            let _g = stats.busy_guard();
            let _q0 = stats.queue_guard(0);
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        {
            let _g = stats.busy_guard();
            let _q1 = stats.queue_guard(1);
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let s = stats.snapshot();
        assert_eq!(s.queue_count, 2);
        assert!(s.queue_busy_ns[0] > 0 && s.queue_busy_ns[1] > 0);
        // per-queue unions partition the work here (disjoint windows),
        // so each is below the engine-wide union
        assert!(s.queue_busy_ns[0] <= s.busy_ns);
        assert!(s.queue_busy_ns[1] <= s.busy_ns);
        assert!(s.queue_busy_secs(0) > 0.0);
        assert_eq!(s.queue_busy_secs(MAX_QUEUES + 1), 0.0);
        // ids past the cap are ignored per-queue, not crashed on
        let _far = stats.queue_guard(MAX_QUEUES + 3);
    }

    #[test]
    fn direct_engine_attributes_busy_to_device_queues() {
        let tmp = std::env::temp_dir().join(format!("ma-qbusy-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        let eng = DirectEngine::new(&tmp, 2, 1 << 24, 1).unwrap();
        // striped across both devices -> both queues go busy
        eng.write("t", &vec![7u8; 64_000]).unwrap();
        let mut out = vec![0u8; 64_000];
        eng.read("t", &mut out).unwrap();
        let s = eng.stats();
        assert_eq!(s.queue_count, 2);
        assert!(s.queue_busy_ns[0] > 0, "device 0 never went busy");
        assert!(s.queue_busy_ns[1] > 0, "device 1 never went busy");
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn prop_write_read_roundtrip_both_engines() {
        let tmp = std::env::temp_dir().join(format!("ma-ssd-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        check("ssd-roundtrip", Config { cases: 24, ..Default::default() }, |rng, size| {
            let dir = tmp.join(format!("c{}", rng.next_u64()));
            for eng in engines(&dir) {
                let mut store: std::collections::HashMap<String, Vec<u8>> =
                    Default::default();
                for i in 0..rng.range(1, 8) {
                    // tensor sizes are fixed for a training run: reuse
                    // of a key always carries the same length (the
                    // direct engine's extents are immutable by design)
                    let key_id = rng.below(4);
                    let key = format!("t{key_id}");
                    let n = match store.get(&key) {
                        Some(prev) => prev.len(),
                        None => rng.range(1, size.max(2) * 16),
                    };
                    let data: Vec<u8> =
                        (0..n).map(|j| ((i * 31 + j * 7) % 256) as u8).collect();
                    eng.write(&key, &data).map_err(|e| e.to_string())?;
                    store.insert(key, data);
                }
                for (key, want) in &store {
                    let mut out = vec![0u8; want.len()];
                    eng.read(key, &mut out).map_err(|e| e.to_string())?;
                    prop_assert!(
                        &out == want,
                        "{}: key {key} corrupted ({} bytes)",
                        eng.label(),
                        want.len()
                    );
                    prop_assert!(
                        eng.len_of(key) == Some(want.len()),
                        "len_of mismatch"
                    );
                }
            }
            std::fs::remove_dir_all(&dir).ok();
            Ok(())
        });
        std::fs::remove_dir_all(&tmp).ok();
    }
}
