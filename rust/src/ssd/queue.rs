//! Async multi-queue I/O layer: submission/completion queues over
//! persistent worker pools (the NVMe driver analog, §IV-E).
//!
//! Real NVMe devices expose many submission/completion queue pairs and
//! reach rated bandwidth only when enough requests are in flight across
//! them.  The seed code fanned each tensor's extents out with per-call
//! scoped threads — paying a spawn/join round trip on every transfer
//! and leaving nothing in flight between calls.  This module replaces
//! that with a persistent executor per queue:
//!
//! ```text
//!  producer threads                 worker pool (persistent)
//!  ───────────────                  ───────────────────────
//!  submit(job) ──► [ submission queue (FIFO) ] ──► worker 0 ──┐
//!                                  │                          │ out-of-order
//!                                  ├───────────► worker 1 ──┤ execution
//!                                  └───────────► worker N ──┘
//!                                                      │
//!                      completion: per-request handle ◄┘
//!                      (Completion slot + condvar — the CQ entry)
//! ```
//!
//! Three surfaces are built on it:
//!
//! - [`IoExecutor::submit`] — fire an owned (`'static`) job; used by
//!   [`AsyncEngine`] for whole-tensor async reads/writes.
//! - [`io_scope`] — scoped fan-out of *borrowing* jobs (disjoint
//!   extent slices of one tensor); blocks until every job in the scope
//!   completed, which is what makes lending stack borrows sound.
//! - [`AsyncEngine`] — `submit_read`/`submit_write` returning
//!   [`IoHandle`]s, layering an async surface over any [`NvmeEngine`]
//!   while the sync trait calls keep working unchanged.
//!
//! The queue workers are *transfer* workers only.  Under the staged-
//! tile model, dtype conversion never runs here: a fetch job completes
//! as soon as the bytes are staged, and the upconvert/downconvert
//! stages run on the compute-side [`crate::util::stage::StageExecutor`]
//! so decode of tile *k* overlaps the device read of tile *k+1*.  Tile
//! transfers ride the ranged surface
//! ([`AsyncEngine::submit_read_at_lease`] /
//! [`AsyncEngine::submit_write_at_lease`]): the buffer is a pinned
//! [`Lease`] from the [`crate::pinned::PinnedArena`] — not a pooled
//! `Vec` — so every byte a tile keeps in flight is on the arena ledger
//! and inside the pinned budget, and the lease travels through the
//! handle back to the caller (or drops, releasing its extent, if the
//! pipeline is torn down mid-flight).
//!
//! Fault tolerance composes by layering, not by queue logic: every
//! submit path closes over the wrapped [`NvmeEngine`] handed to
//! [`AsyncEngine::new`] and calls its sync surface from the worker, so
//! stacking a [`super::RetryEngine`] under the queue gives *every*
//! async submission — whole-tensor and ranged alike — the same bounded
//! retry/backoff semantics as direct sync calls, with no retry code in
//! the workers themselves.
//!
//! ## Health tracking and hedged reads
//!
//! The executor carries one [`HealthTracker`]: every submission's
//! *service* latency (time inside the engine call, excluding queue
//! wait — deep prefetch queues must not look like a sick device) and
//! outcome are recorded from the worker, feeding the EWMA/p99 and the
//! quarantine state machine the governors read.
//!
//! With a per-op deadline configured ([`AsyncEngine::with_deadline`]),
//! owned-buffer reads become *hedged*: if the primary submission has
//! not completed by the time a blocked waiter has given it
//! [`HealthTracker::hedge_delay`], the waiter records a timeout and
//! re-submits the same read on the same queue into a fresh buffer —
//! first completion wins, the loser's result is dropped.  The hedge
//! clock starts when the caller blocks in [`IoHandle::wait`], so
//! prefetched handles that are already resolved by wait time never
//! hedge.  Lease-backed reads are *not* hedged (two submissions
//! filling one pinned lease concurrently would be a data race); they
//! still feed the health tracker.

use std::marker::PhantomData;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::pinned::Lease;
use crate::util::events::{JobId, MAX_JOB_LANES};

use super::health::HealthTracker;
use super::sched::DwrrQueue;
use super::NvmeEngine;

type Task = Box<dyn FnOnce() + Send + 'static>;

struct Sq {
    tasks: DwrrQueue<Task>,
    shutdown: bool,
}

/// Per-job-lane service accounting, charged by the workers as tasks
/// execute (ops dispatched, cost bytes, wall-clock busy time).
#[derive(Default)]
struct LaneStats {
    ops: AtomicU64,
    bytes: AtomicU64,
    busy_ns: AtomicU64,
}

struct QueueShared {
    sq: Mutex<Sq>,
    cv: Condvar,
    lanes: [LaneStats; MAX_JOB_LANES],
}

/// Persistent worker pool draining one weighted-fair submission queue.
///
/// Workers live for the executor's lifetime; `Drop` drains the queue
/// and joins them.  Jobs run out of order across workers — ordering,
/// when needed, is the caller's business (see the swapper's reorder
/// window).
///
/// Submissions carry a [`JobId`] lane and a byte cost; dispatch is
/// deficit-weighted round robin ([`DwrrQueue`]) across lanes, FIFO
/// within a lane.  Pre-tenancy call sites go through [`Self::submit`],
/// which tags [`JobId::HOST`] — with a single lane active the policy
/// degenerates to exactly the old FIFO.
pub struct IoExecutor {
    shared: Arc<QueueShared>,
    workers: Vec<JoinHandle<()>>,
    /// Device-health view over everything submitted through this pool
    /// (latency EWMA/p99, error/timeout meters, quarantine machine).
    health: Arc<HealthTracker>,
}

impl IoExecutor {
    pub fn new(workers: usize) -> Self {
        Self::with_thread_prefix(workers, "ma-ioq")
    }

    /// [`Self::new`] with a custom worker-thread name prefix — the
    /// same pool also serves as the compute-side
    /// [`crate::util::stage::StageExecutor`], which only differs in
    /// what runs on it.
    pub fn with_thread_prefix(workers: usize, prefix: &str) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new(QueueShared {
            sq: Mutex::new(Sq { tasks: DwrrQueue::new(), shutdown: false }),
            cv: Condvar::new(),
            lanes: Default::default(),
        });
        let handles = (0..workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("{prefix}-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { shared, workers: handles, health: Arc::new(HealthTracker::default()) }
    }

    /// The device-health tracker fed by every engine call submitted
    /// through this executor.
    pub fn health(&self) -> &Arc<HealthTracker> {
        &self.health
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue an owned job on the host lane; returns immediately.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.submit_for(JobId::HOST, 1, job);
    }

    /// Enqueue an owned job on `job`'s lane with a byte `cost` (the
    /// weighted-fair scheduling currency; use the transfer size, or 1
    /// for control work).
    pub fn submit_for<F: FnOnce() + Send + 'static>(&self, job: JobId, cost: u64, f: F) {
        self.push(job.lane(), cost, Box::new(f));
    }

    /// Set a job's scheduling weight (clamped to ≥ 1; default 1).
    pub fn set_weight(&self, job: JobId, weight: u32) {
        self.shared.sq.lock().unwrap().tasks.set_weight(job.lane(), weight);
    }

    /// Overlay this executor's per-job service counters onto `snap`.
    /// Lane totals accumulate across the executor's lifetime, summed
    /// over every engine submitting through it.
    pub fn fill_job_lanes(&self, snap: &mut super::IoSnapshot) {
        for (i, lane) in self.shared.lanes.iter().enumerate() {
            snap.job_ops[i] = lane.ops.load(Ordering::Relaxed);
            snap.job_bytes[i] = lane.bytes.load(Ordering::Relaxed);
            snap.job_busy_ns[i] = lane.busy_ns.load(Ordering::Relaxed);
        }
    }

    fn push(&self, lane: usize, cost: u64, task: Task) {
        let mut sq = self.shared.sq.lock().unwrap();
        sq.tasks.push(lane, cost, task);
        drop(sq);
        self.shared.cv.notify_one();
    }
}

impl Drop for IoExecutor {
    fn drop(&mut self) {
        self.shared.sq.lock().unwrap().shutdown = true;
        self.shared.cv.notify_all();
        let me = std::thread::current().id();
        for h in self.workers.drain(..) {
            // the last owner of the executor can be one of its own
            // workers (an in-flight job dropping its context Arc);
            // joining self would deadlock that worker forever, so the
            // current thread is detached instead — it exits on its own
            // once it observes `shutdown` (its queue is already drained
            // or being drained by this very loop's siblings)
            if h.thread().id() == me {
                continue;
            }
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<QueueShared>) {
    loop {
        let (lane, cost, task) = {
            let mut sq = shared.sq.lock().unwrap();
            loop {
                if let Some(t) = sq.tasks.pop() {
                    break t;
                }
                if sq.shutdown {
                    return;
                }
                sq = shared.cv.wait(sq).unwrap();
            }
        };
        // a panicking job must not kill the worker: queued tasks would
        // never pop and their waiters would hang.  The panic is
        // contained here; an abandoned Completer (its Drop runs during
        // the unwind) surfaces as an error at the handle.
        let t0 = Instant::now();
        let _ = std::panic::catch_unwind(AssertUnwindSafe(task));
        let stats = &shared.lanes[lane.min(MAX_JOB_LANES - 1)];
        stats.ops.fetch_add(1, Ordering::Relaxed);
        stats.bytes.fetch_add(cost, Ordering::Relaxed);
        stats
            .busy_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Completion: the CQ-entry analog — a one-shot slot + condvar.

enum Slot<T> {
    Pending,
    Done(T),
    /// The fulfilling side was dropped without completing (worker
    /// died); waiters get an error instead of hanging.
    Abandoned,
}

struct CompletionCell<T> {
    slot: Mutex<Slot<T>>,
    cv: Condvar,
}

/// Waiting side of a one-shot completion.
pub struct Completion<T> {
    cell: Arc<CompletionCell<T>>,
}

/// Fulfilling side of a one-shot completion.
pub struct Completer<T> {
    cell: Option<Arc<CompletionCell<T>>>,
}

/// Create a linked (fulfiller, waiter) pair.
pub fn completion_pair<T>() -> (Completer<T>, Completion<T>) {
    let cell = Arc::new(CompletionCell {
        slot: Mutex::new(Slot::Pending),
        cv: Condvar::new(),
    });
    (Completer { cell: Some(Arc::clone(&cell)) }, Completion { cell })
}

impl<T> Completer<T> {
    pub fn complete(mut self, value: T) {
        let cell = self.cell.take().expect("completer fires once");
        *cell.slot.lock().unwrap() = Slot::Done(value);
        cell.cv.notify_all();
    }
}

impl<T> Drop for Completer<T> {
    fn drop(&mut self) {
        if let Some(cell) = self.cell.take() {
            let mut slot = cell.slot.lock().unwrap();
            if matches!(*slot, Slot::Pending) {
                *slot = Slot::Abandoned;
                cell.cv.notify_all();
            }
        }
    }
}

impl<T> Completion<T> {
    /// Block until the value arrives (or the completer vanished).
    pub fn wait(self) -> anyhow::Result<T> {
        let mut slot = self.cell.slot.lock().unwrap();
        loop {
            match std::mem::replace(&mut *slot, Slot::Pending) {
                Slot::Done(v) => return Ok(v),
                Slot::Abandoned => {
                    anyhow::bail!("i/o completion abandoned (worker dropped request)")
                }
                Slot::Pending => slot = self.cell.cv.wait(slot).unwrap(),
            }
        }
    }

    /// Non-blocking readiness probe.
    pub fn is_ready(&self) -> bool {
        !matches!(*self.cell.slot.lock().unwrap(), Slot::Pending)
    }

    /// Block until the slot resolves or `dur` elapses; `true` when
    /// resolved (the value stays in the slot for a later [`Self::wait`]).
    pub fn wait_ready_for(&self, dur: Duration) -> bool {
        let deadline = Instant::now() + dur;
        let mut slot = self.cell.slot.lock().unwrap();
        while matches!(*slot, Slot::Pending) {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (s, _) = self.cell.cv.wait_timeout(slot, deadline - now).unwrap();
            slot = s;
        }
        true
    }
}

/// The hedge arm of an [`IoHandle`]: if the primary submission is
/// still pending `after` into a blocking wait, the waiter records a
/// timeout and fires the re-submission.
struct Hedge {
    after: Duration,
    health: Arc<HealthTracker>,
    fire: Box<dyn FnOnce() + Send>,
}

/// Handle to one in-flight async I/O; resolves to the operation's
/// buffer so callers can recycle allocations.
pub struct IoHandle<T> {
    completion: Completion<anyhow::Result<T>>,
    hedge: Option<Hedge>,
}

impl<T> IoHandle<T> {
    /// Create an unresolved handle plus its fulfilling side.
    pub fn pair() -> (Completer<anyhow::Result<T>>, IoHandle<T>) {
        let (completer, completion) = completion_pair();
        (completer, IoHandle { completion, hedge: None })
    }

    /// Arm this handle to hedge: a blocking [`Self::wait`] that is
    /// still pending `after` in fires `fire` (once) and keeps waiting
    /// for whichever submission completes first.
    fn with_hedge(
        mut self,
        after: Duration,
        health: Arc<HealthTracker>,
        fire: Box<dyn FnOnce() + Send>,
    ) -> Self {
        self.hedge = Some(Hedge { after, health, fire });
        self
    }

    /// Block until the request completes.  On a hedged handle, a
    /// primary submission outliving its hedge delay is recorded as a
    /// timeout and raced against a re-submission (first wins).
    pub fn wait(mut self) -> anyhow::Result<T> {
        if let Some(h) = self.hedge.take() {
            if !self.completion.wait_ready_for(h.after) {
                h.health.record_timeout();
                h.health.record_hedge();
                (h.fire)();
            }
        }
        self.completion.wait()?
    }

    pub fn is_ready(&self) -> bool {
        self.completion.is_ready()
    }
}

// ---------------------------------------------------------------------------
// Scoped fan-out: jobs that borrow the caller's stack.

struct ScopeState {
    pending: Mutex<usize>,
    cv: Condvar,
    errors: Mutex<Vec<anyhow::Error>>,
}

/// A fan-out scope: jobs submitted through it may borrow data alive
/// for `'scope`; the scope blocks (in [`io_scope`] and in `Drop`, so
/// also on panic) until every job finished.
pub struct IoScope<'scope> {
    state: Arc<ScopeState>,
    _scope: PhantomData<&'scope mut &'scope ()>,
}

impl<'scope> IoScope<'scope> {
    /// Queue `job` on `exec`. Errors are collected and surfaced by
    /// [`io_scope`]'s return value (first error wins).
    pub fn submit<F>(&self, exec: &IoExecutor, job: F)
    where
        F: FnOnce() -> anyhow::Result<()> + Send + 'scope,
    {
        *self.state.pending.lock().unwrap() += 1;
        let state = Arc::clone(&self.state);
        let wrapped: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
            match std::panic::catch_unwind(AssertUnwindSafe(job)) {
                Ok(Ok(())) => {}
                Ok(Err(e)) => state.errors.lock().unwrap().push(e),
                Err(_) => state
                    .errors
                    .lock()
                    .unwrap()
                    .push(anyhow::anyhow!("i/o job panicked")),
            }
            let mut n = state.pending.lock().unwrap();
            *n -= 1;
            if *n == 0 {
                state.cv.notify_all();
            }
        });
        // SAFETY: the job may borrow data that only lives for 'scope.
        // Soundness rests on the invariant that this scope never
        // outlives those borrows *while jobs run*: `io_scope` calls
        // `wait_all` before returning, and `Drop` calls it again on
        // every exit path (including unwinding), so no job can still be
        // executing once 'scope ends.  The wrapper also counts down on
        // panic (`catch_unwind` above), so `wait_all` cannot hang.
        let wrapped: Task = unsafe {
            std::mem::transmute::<
                Box<dyn FnOnce() + Send + 'scope>,
                Box<dyn FnOnce() + Send + 'static>,
            >(wrapped)
        };
        exec.push(JobId::HOST.lane(), 1, wrapped);
    }

    fn wait_all(&self) {
        let mut n = self.state.pending.lock().unwrap();
        while *n > 0 {
            n = self.state.cv.wait(n).unwrap();
        }
    }
}

impl Drop for IoScope<'_> {
    fn drop(&mut self) {
        self.wait_all();
    }
}

/// Run `f` with a fan-out scope, wait for every submitted job, and
/// return the first job error (or `f`'s own error).
pub fn io_scope<'scope, F>(f: F) -> anyhow::Result<()>
where
    F: FnOnce(&IoScope<'scope>) -> anyhow::Result<()>,
{
    let scope = IoScope {
        state: Arc::new(ScopeState {
            pending: Mutex::new(0),
            cv: Condvar::new(),
            errors: Mutex::new(Vec::new()),
        }),
        _scope: PhantomData,
    };
    let submitted = f(&scope);
    scope.wait_all();
    submitted?;
    let mut errs = scope.state.errors.lock().unwrap();
    match errs.drain(..).next() {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

// ---------------------------------------------------------------------------
// AsyncEngine: the async NvmeEngine surface.

/// Async facade over any [`NvmeEngine`]: `submit_*` enqueue on a
/// shared executor and return [`IoHandle`]s; the sync [`NvmeEngine`]
/// methods delegate straight to the wrapped engine, so existing
/// callers keep working.
///
/// Every submission is tagged with the engine's [`JobId`] (default
/// [`JobId::HOST`]; see [`Self::for_job`]) and the transfer's byte
/// size, which together drive the executor's weighted-fair dispatch
/// and per-job service accounting.
#[derive(Clone)]
pub struct AsyncEngine {
    inner: Arc<dyn NvmeEngine>,
    exec: Arc<IoExecutor>,
    job: JobId,
    /// Per-op deadline; `Some` arms hedged reads (see module docs).
    deadline: Option<Duration>,
}

impl AsyncEngine {
    pub fn new(inner: Arc<dyn NvmeEngine>, workers: usize) -> Self {
        Self {
            inner,
            exec: Arc::new(IoExecutor::new(workers)),
            job: JobId::HOST,
            deadline: None,
        }
    }

    /// Share an existing executor (one queue layer per process, not
    /// one per call site).
    pub fn with_executor(inner: Arc<dyn NvmeEngine>, exec: Arc<IoExecutor>) -> Self {
        Self { inner, exec, job: JobId::HOST, deadline: None }
    }

    /// Tag every submission from this handle with `job`'s lane.
    pub fn for_job(mut self, job: JobId) -> Self {
        self.job = job;
        self
    }

    /// Arm per-op deadlines: owned-buffer reads whose primary
    /// submission stalls past [`HealthTracker::hedge_delay`] of
    /// `deadline` are hedged with a re-submission on the same queue
    /// (first completion wins).  `None` disables hedging (default).
    pub fn with_deadline(mut self, deadline: Option<Duration>) -> Self {
        self.deadline = deadline;
        self
    }

    pub fn job(&self) -> JobId {
        self.job
    }

    pub fn engine(&self) -> &Arc<dyn NvmeEngine> {
        &self.inner
    }

    pub fn executor(&self) -> &Arc<IoExecutor> {
        &self.exec
    }

    /// Submit an owned-buffer read with optional hedging.  `primary`
    /// consumes the caller's buffer; `backup` must produce the same
    /// bytes into a fresh buffer and is submitted only if the primary
    /// outlives the hedge delay of a blocking wait.  First completion
    /// wins the shared completer; the loser's result is dropped.
    fn submit_hedged<T, P, B>(&self, cost: u64, primary: P, backup: B) -> IoHandle<T>
    where
        T: Send + 'static,
        P: FnOnce() -> anyhow::Result<T> + Send + 'static,
        B: FnOnce() -> anyhow::Result<T> + Send + 'static,
    {
        let (completer, handle) = IoHandle::pair();
        let health = Arc::clone(self.exec.health());
        let Some(deadline) = self.deadline else {
            // unhedged: the completer rides the closure directly, so a
            // panicking engine still surfaces as Abandoned at the handle
            self.exec.submit_for(self.job, cost, move || {
                let t0 = Instant::now();
                let res = primary();
                health.record(t0.elapsed(), res.is_ok());
                completer.complete(res);
            });
            return handle;
        };
        // hedged: both submissions share one take-once completer slot.
        // Panics are converted to errors here (instead of riding the
        // worker's catch_unwind into an Abandoned slot) because the
        // completer must survive in the shared slot for whichever arm
        // finishes first.
        let slot = Arc::new(Mutex::new(Some(completer)));
        let after = health.hedge_delay(deadline);
        let fire = {
            let slot = Arc::clone(&slot);
            let health = Arc::clone(&health);
            let exec = Arc::clone(&self.exec);
            let job = self.job;
            Box::new(move || {
                exec.submit_for(job, cost, move || {
                    let t0 = Instant::now();
                    let res = run_caught(backup);
                    health.record(t0.elapsed(), res.is_ok());
                    if let Some(c) = slot.lock().unwrap().take() {
                        c.complete(res);
                    }
                });
            })
        };
        {
            let slot = Arc::clone(&slot);
            let health = Arc::clone(&health);
            self.exec.submit_for(self.job, cost, move || {
                let t0 = Instant::now();
                let res = run_caught(primary);
                health.record(t0.elapsed(), res.is_ok());
                if let Some(c) = slot.lock().unwrap().take() {
                    c.complete(res);
                }
            });
        }
        handle.with_hedge(after, health, fire)
    }

    /// Async read of `key` into `buf` (must match the stored length);
    /// the filled buffer comes back through the handle.  Hedged under
    /// a deadline ([`Self::with_deadline`]).
    pub fn submit_read(&self, key: String, mut buf: Vec<u8>) -> IoHandle<Vec<u8>> {
        let eng = Arc::clone(&self.inner);
        let eng2 = Arc::clone(&self.inner);
        let key2 = key.clone();
        let len = buf.len();
        self.submit_hedged(
            len as u64,
            move || eng.read(&key, &mut buf).map(move |()| buf),
            move || {
                let mut b = vec![0u8; len];
                eng2.read(&key2, &mut b).map(move |()| b)
            },
        )
    }

    /// Async ranged read: fill `buf` from byte `offset` of `key`'s
    /// value.  The owned-buffer twin of [`Self::submit_read_at_lease`]
    /// for callers staging outside the pinned arena (budget-degraded
    /// fetches, scratch reads).  Hedged under a deadline.
    pub fn submit_read_at(
        &self,
        key: String,
        offset: usize,
        mut buf: Vec<u8>,
    ) -> IoHandle<Vec<u8>> {
        let eng = Arc::clone(&self.inner);
        let eng2 = Arc::clone(&self.inner);
        let key2 = key.clone();
        let len = buf.len();
        self.submit_hedged(
            len as u64,
            move || eng.read_at(&key, offset, &mut buf).map(move |()| buf),
            move || {
                let mut b = vec![0u8; len];
                eng2.read_at(&key2, offset, &mut b).map(move |()| b)
            },
        )
    }

    /// Async write of `data` under `key`; the buffer comes back for
    /// reuse once the write is durable in the engine.
    pub fn submit_write(&self, key: String, data: Vec<u8>) -> IoHandle<Vec<u8>> {
        let (completer, handle) = IoHandle::pair();
        let eng = Arc::clone(&self.inner);
        let health = Arc::clone(self.exec.health());
        self.exec.submit_for(self.job, data.len() as u64, move || {
            let t0 = Instant::now();
            let res = eng.write(&key, &data);
            health.record(t0.elapsed(), res.is_ok());
            completer.complete(res.map(move |()| data));
        });
        handle
    }

    /// [`Self::submit_read`] for f32 tensors (no copy: the engine
    /// reads straight into the vector's bytes).  Hedged under a
    /// deadline.
    pub fn submit_read_f32(&self, key: String, mut buf: Vec<f32>) -> IoHandle<Vec<f32>> {
        let eng = Arc::clone(&self.inner);
        let eng2 = Arc::clone(&self.inner);
        let key2 = key.clone();
        let len = buf.len();
        self.submit_hedged(
            (len * 4) as u64,
            move || {
                eng.read(&key, crate::dtype::f32s_as_bytes_mut(&mut buf))
                    .map(move |()| buf)
            },
            move || {
                let mut b = vec![0f32; len];
                eng2.read(&key2, crate::dtype::f32s_as_bytes_mut(&mut b))
                    .map(move |()| b)
            },
        )
    }

    /// [`Self::submit_write`] for f32 tensors.
    pub fn submit_write_f32(&self, key: String, data: Vec<f32>) -> IoHandle<Vec<f32>> {
        let (completer, handle) = IoHandle::pair();
        let eng = Arc::clone(&self.inner);
        let health = Arc::clone(self.exec.health());
        self.exec.submit_for(self.job, (data.len() * 4) as u64, move || {
            let t0 = Instant::now();
            let res = eng.write(&key, crate::dtype::f32s_as_bytes(&data));
            health.record(t0.elapsed(), res.is_ok());
            completer.complete(res.map(move |()| data));
        });
        handle
    }

    /// Async ranged read of one tile: fill the pinned lease from byte
    /// `offset` of `key`'s value.  The lease comes back through the
    /// handle; dropped handles drop the lease, releasing its extent.
    /// Never hedged — two submissions filling one lease would race —
    /// but still health-recorded.
    pub fn submit_read_at_lease(
        &self,
        key: String,
        offset: usize,
        mut buf: Lease,
    ) -> IoHandle<Lease> {
        let (completer, handle) = IoHandle::pair();
        let eng = Arc::clone(&self.inner);
        let health = Arc::clone(self.exec.health());
        let cost = buf.as_slice().len() as u64;
        self.exec.submit_for(self.job, cost, move || {
            let t0 = Instant::now();
            let res = eng.read_at(&key, offset, buf.as_mut_slice());
            health.record(t0.elapsed(), res.is_ok());
            completer.complete(res.map(move |()| buf));
        });
        handle
    }

    /// Async ranged write of a *sub-range* of a shared pinned lease
    /// into byte `offset` of `key`'s (already reserved) value: bytes
    /// `src_off .. src_off + len` of `buf` land at `offset`.  One
    /// frozen lease can back many concurrent ranged writes to
    /// different keys — the coalesced optimizer's fp16 scatter, where
    /// a single tile's downconvert window fans out to every member
    /// tensor's compute-weight stream it overlaps.
    pub fn submit_write_at_lease_view(
        &self,
        key: String,
        offset: usize,
        buf: Arc<Lease>,
        src_off: usize,
        len: usize,
    ) -> IoHandle<Arc<Lease>> {
        let (completer, handle) = IoHandle::pair();
        let eng = Arc::clone(&self.inner);
        let health = Arc::clone(self.exec.health());
        self.exec.submit_for(self.job, len as u64, move || {
            let t0 = Instant::now();
            let res = if src_off + len <= buf.as_slice().len() {
                eng.write_at(&key, offset, &buf.as_slice()[src_off..src_off + len])
            } else {
                Err(anyhow::anyhow!(
                    "lease-view write past the lease ({src_off}+{len} > {})",
                    buf.as_slice().len()
                ))
            };
            health.record(t0.elapsed(), res.is_ok());
            completer.complete(res.map(move |()| buf));
        });
        handle
    }

    /// Async ranged write of one tile from a pinned lease into byte
    /// `offset` of `key`'s (already reserved) value.
    pub fn submit_write_at_lease(
        &self,
        key: String,
        offset: usize,
        buf: Lease,
    ) -> IoHandle<Lease> {
        let (completer, handle) = IoHandle::pair();
        let eng = Arc::clone(&self.inner);
        let health = Arc::clone(self.exec.health());
        let cost = buf.as_slice().len() as u64;
        self.exec.submit_for(self.job, cost, move || {
            let t0 = Instant::now();
            let res = eng.write_at(&key, offset, buf.as_slice());
            health.record(t0.elapsed(), res.is_ok());
            completer.complete(res.map(move |()| buf));
        });
        handle
    }
}

/// Run `op`, converting a panic into an `Err` (hedged arms keep the
/// shared completer alive, so the Abandoned-on-unwind path cannot be
/// relied on there).
fn run_caught<T>(op: impl FnOnce() -> anyhow::Result<T>) -> anyhow::Result<T> {
    match std::panic::catch_unwind(AssertUnwindSafe(op)) {
        Ok(res) => res,
        Err(_) => Err(anyhow::anyhow!("i/o job panicked")),
    }
}

impl NvmeEngine for AsyncEngine {
    fn write(&self, key: &str, data: &[u8]) -> anyhow::Result<()> {
        self.inner.write(key, data)
    }

    fn read(&self, key: &str, out: &mut [u8]) -> anyhow::Result<()> {
        self.inner.read(key, out)
    }

    fn read_at(&self, key: &str, offset: usize, out: &mut [u8]) -> anyhow::Result<()> {
        self.inner.read_at(key, offset, out)
    }

    fn write_at(&self, key: &str, offset: usize, data: &[u8]) -> anyhow::Result<()> {
        self.inner.write_at(key, offset, data)
    }

    fn flush(&self, key: &str) -> anyhow::Result<()> {
        self.inner.flush(key)
    }

    fn reserve(&self, key: &str, len: usize) -> anyhow::Result<()> {
        self.inner.reserve(key, len)
    }

    fn len_of(&self, key: &str) -> Option<usize> {
        self.inner.len_of(key)
    }

    fn stats(&self) -> super::IoSnapshot {
        // overlay the executor's per-job service lanes: the wrapped
        // engine meters transfers, the executor meters queue service
        let mut s = self.inner.stats();
        self.exec.fill_job_lanes(&mut s);
        s
    }

    fn label(&self) -> &'static str {
        self.inner.label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssd::DirectEngine;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executor_runs_all_jobs() {
        let exec = IoExecutor::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            exec.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(exec); // drains queue + joins workers
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn scope_jobs_borrow_and_errors_surface() {
        let exec = IoExecutor::new(3);
        let mut data = vec![0u64; 64];
        let r = io_scope(|s| {
            for (i, chunk) in data.chunks_mut(8).enumerate() {
                s.submit(&exec, move || {
                    for (j, x) in chunk.iter_mut().enumerate() {
                        *x = (i * 8 + j) as u64;
                    }
                    Ok(())
                });
            }
            Ok(())
        });
        assert!(r.is_ok());
        assert!(data.iter().enumerate().all(|(i, &x)| x == i as u64));

        let r = io_scope(|s| {
            s.submit(&exec, || Ok(()));
            s.submit(&exec, || anyhow::bail!("boom"));
            Ok(())
        });
        assert!(r.unwrap_err().to_string().contains("boom"));
    }

    #[test]
    fn scope_survives_panicking_job() {
        let exec = IoExecutor::new(2);
        let r = io_scope(|s| {
            s.submit(&exec, || panic!("job panic"));
            s.submit(&exec, || Ok(()));
            Ok(())
        });
        assert!(r.unwrap_err().to_string().contains("panicked"));
        // executor still usable afterwards
        let done = Arc::new(AtomicUsize::new(0));
        let d = Arc::clone(&done);
        exec.submit(move || {
            d.fetch_add(1, Ordering::Relaxed);
        });
        drop(exec);
        assert_eq!(done.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn dropping_last_executor_ref_from_its_own_worker_does_not_deadlock() {
        // an in-flight job can hold the last Arc to its executor (the
        // swapper's FetchCtx shape); dropping it runs IoExecutor::drop
        // on a worker thread, which must not join itself
        let exec = Arc::new(IoExecutor::new(1));
        let exec2 = Arc::clone(&exec);
        let (completer, handle): (_, IoHandle<u32>) = IoHandle::pair();
        exec.submit(move || {
            // let main's ref drop first so ours is the final one
            std::thread::sleep(std::time::Duration::from_millis(100));
            drop(exec2); // Drop runs here, on this worker
            completer.complete(Ok(7)); // reached only if Drop returned
        });
        drop(exec);
        assert_eq!(handle.wait().unwrap(), 7);
    }

    #[test]
    fn completion_abandonment_is_an_error_not_a_hang() {
        let (completer, handle): (_, IoHandle<u32>) = IoHandle::pair();
        drop(completer);
        assert!(handle.wait().is_err());
    }

    #[test]
    fn panicking_submit_job_neither_kills_worker_nor_hangs_waiters() {
        let exec = IoExecutor::new(1); // single worker: a dead worker = deadlock
        let (completer, handle): (_, IoHandle<u32>) = IoHandle::pair();
        exec.submit(move || {
            let _moved_in = completer; // dropped mid-unwind -> Abandoned
            panic!("job panic");
        });
        // the waiter gets an error instead of hanging…
        assert!(handle.wait().is_err());
        // …and the lone worker survives to run the next job
        let (completer, handle): (_, IoHandle<u32>) = IoHandle::pair();
        exec.submit(move || completer.complete(Ok(7)));
        assert_eq!(handle.wait().unwrap(), 7);
    }

    #[test]
    fn async_engine_roundtrip_out_of_order_completion() {
        let dir = std::env::temp_dir().join(format!("ma-aio-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let inner: Arc<dyn NvmeEngine> =
            Arc::new(DirectEngine::new(&dir, 2, 1 << 24, 2).unwrap());
        let aio = AsyncEngine::new(Arc::clone(&inner), 4);

        let mut writes = Vec::new();
        for i in 0..16usize {
            let data = vec![i as u8; 4096 + i * 513];
            writes.push((i, aio.submit_write(format!("k{i}"), data)));
        }
        for (_, h) in writes {
            h.wait().unwrap();
        }
        let mut reads = Vec::new();
        for i in 0..16usize {
            let buf = vec![0u8; 4096 + i * 513];
            reads.push((i, aio.submit_read(format!("k{i}"), buf)));
        }
        for (i, h) in reads {
            let got = h.wait().unwrap();
            assert_eq!(got.len(), 4096 + i * 513);
            assert!(got.iter().all(|&b| b == i as u8), "k{i} corrupted");
        }
        // sync surface still works on the same engine
        let mut out = vec![0u8; 4096];
        aio.read("k0", &mut out).unwrap();
        assert!(out.iter().all(|&b| b == 0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lease_tile_reads_and_writes_roundtrip() {
        use crate::bufpool::test_util::test_arena;
        use crate::pinned::{Cat, Mode};

        let dir = std::env::temp_dir().join(format!("ma-aiol-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let inner: Arc<dyn NvmeEngine> =
            Arc::new(DirectEngine::new(&dir, 2, 1 << 24, 1).unwrap());
        let aio = AsyncEngine::new(Arc::clone(&inner), 3);
        let arena = test_arena(Mode::Real);

        let n = 50_000usize;
        let tile = 9001usize; // deliberately unaligned tiles
        aio.reserve("t", n).unwrap();
        // write the value tile-by-tile from pinned leases
        let mut writes = Vec::new();
        let mut off = 0;
        while off < n {
            let len = tile.min(n - off);
            let mut l = arena.lease(len, Cat::OptimBuf).unwrap();
            for (i, b) in l.as_mut_slice().iter_mut().enumerate() {
                *b = ((off + i) % 253) as u8;
            }
            writes.push(aio.submit_write_at_lease("t".into(), off, l));
            off += len;
        }
        for h in writes {
            h.wait().unwrap(); // lease returns, then drops -> extent recycles
        }
        // read it back tile-by-tile through leases, out of order
        let mut reads = Vec::new();
        let mut off = 0;
        while off < n {
            let len = tile.min(n - off);
            let l = arena.lease(len, Cat::OptimBuf).unwrap();
            reads.push((off, aio.submit_read_at_lease("t".into(), off, l)));
            off += len;
        }
        for (off, h) in reads.into_iter().rev() {
            let l = h.wait().unwrap();
            assert!(
                l.as_slice()
                    .iter()
                    .enumerate()
                    .all(|(i, &b)| b == ((off + i) % 253) as u8),
                "tile @{off} corrupted"
            );
        }
        assert_eq!(arena.stats().requested_bytes, 0, "all leases returned");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shared_lease_view_writes_scatter_one_lease_to_many_keys() {
        use crate::bufpool::test_util::test_arena;
        use crate::pinned::{Cat, Mode};

        let dir = std::env::temp_dir().join(format!("ma-aiov-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let inner: Arc<dyn NvmeEngine> =
            Arc::new(DirectEngine::new(&dir, 2, 1 << 24, 1).unwrap());
        let aio = AsyncEngine::new(Arc::clone(&inner), 3);
        let arena = test_arena(Mode::Real);

        // one frozen lease holds 3 members' worth of bytes; each member
        // key receives its sub-range at its own destination offset
        let spans = [(0usize, 100usize), (100, 57), (157, 99)];
        let total = 256usize;
        let mut l = arena.lease(total, Cat::SwapBuf).unwrap();
        for (i, b) in l.as_mut_slice().iter_mut().enumerate() {
            *b = (i % 251) as u8;
        }
        let shared = l.into_shared();
        let mut handles = Vec::new();
        for (m, (src, len)) in spans.iter().enumerate() {
            let key = format!("m{m}");
            aio.reserve(&key, len + 8).unwrap();
            handles.push(aio.submit_write_at_lease_view(
                key,
                8, // member-side destination offset
                Arc::clone(&shared),
                *src,
                *len,
            ));
        }
        for h in handles {
            h.wait().unwrap();
        }
        drop(shared);
        for (m, (src, len)) in spans.iter().enumerate() {
            let mut out = vec![0u8; *len];
            aio.read_at(&format!("m{m}"), 8, &mut out).unwrap();
            assert!(
                out.iter().enumerate().all(|(i, &b)| b == ((src + i) % 251) as u8),
                "member {m} corrupted"
            );
        }
        // an out-of-lease view surfaces as an error, not UB or a hang
        let l = arena.lease(16, Cat::SwapBuf).unwrap().into_shared();
        aio.reserve("big", 64).unwrap();
        assert!(aio
            .submit_write_at_lease_view("big".into(), 0, l, 8, 16)
            .wait()
            .is_err());
        assert_eq!(arena.stats().requested_bytes, 0, "leases leaked");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn per_job_lanes_meter_service_and_single_lane_stays_fifo() {
        let dir = std::env::temp_dir().join(format!("ma-aioj-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let inner: Arc<dyn NvmeEngine> =
            Arc::new(DirectEngine::new(&dir, 1, 1 << 22, 1).unwrap());
        let exec = Arc::new(IoExecutor::new(2));
        let host = AsyncEngine::with_executor(Arc::clone(&inner), Arc::clone(&exec));
        let j3 = host.clone().for_job(JobId(3));

        let mut handles = Vec::new();
        for i in 0..4usize {
            handles.push(host.submit_write(format!("h{i}"), vec![1u8; 1000]));
            handles.push(j3.submit_write(format!("t{i}"), vec![2u8; 3000]));
        }
        for h in handles {
            h.wait().unwrap();
        }
        let snap = host.stats();
        assert_eq!(snap.job_ops[JobId::HOST.lane()], 4);
        assert_eq!(snap.job_bytes[JobId::HOST.lane()], 4 * 1000);
        assert_eq!(snap.job_ops[JobId(3).lane()], 4);
        assert_eq!(snap.job_bytes[JobId(3).lane()], 4 * 3000);
        assert!(snap.job_busy_ns[JobId(3).lane()] > 0, "service time not metered");
        // untouched lanes stay zero
        assert_eq!(snap.job_ops[1], 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn async_read_error_surfaces() {
        let dir = std::env::temp_dir().join(format!("ma-aio2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let inner: Arc<dyn NvmeEngine> =
            Arc::new(DirectEngine::new(&dir, 1, 1 << 20, 1).unwrap());
        let aio = AsyncEngine::new(inner, 2);
        let h = aio.submit_read("missing".into(), vec![0u8; 16]);
        assert!(h.wait().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Decorator that stalls the first `stalls` reads for `stall` each
    /// (a straggler device), passing everything else straight through.
    struct StallReads {
        inner: Arc<dyn NvmeEngine>,
        stalls: AtomicU64,
        stall: Duration,
    }

    impl NvmeEngine for StallReads {
        fn write(&self, key: &str, data: &[u8]) -> anyhow::Result<()> {
            self.inner.write(key, data)
        }
        fn read(&self, key: &str, out: &mut [u8]) -> anyhow::Result<()> {
            let stall_this = self
                .stalls
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                    v.checked_sub(1)
                })
                .is_ok();
            if stall_this {
                std::thread::sleep(self.stall);
            }
            self.inner.read(key, out)
        }
        fn write_at(&self, key: &str, offset: usize, data: &[u8]) -> anyhow::Result<()> {
            self.inner.write_at(key, offset, data)
        }
        fn len_of(&self, key: &str) -> Option<usize> {
            self.inner.len_of(key)
        }
        fn stats(&self) -> crate::ssd::IoSnapshot {
            self.inner.stats()
        }
        fn label(&self) -> &'static str {
            self.inner.label()
        }
    }

    #[test]
    fn stalled_primary_read_is_hedged_and_first_completion_wins() {
        let dir = std::env::temp_dir().join(format!("ma-hedge-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let base: Arc<dyn NvmeEngine> =
            Arc::new(DirectEngine::new(&dir, 1, 1 << 22, 1).unwrap());
        let stalled: Arc<dyn NvmeEngine> = Arc::new(StallReads {
            inner: base,
            stalls: AtomicU64::new(1),
            stall: Duration::from_millis(400),
        });
        // 2 workers: the hedge must run while the primary is stuck
        let aio = AsyncEngine::new(stalled, 2)
            .with_deadline(Some(Duration::from_millis(25)));
        aio.write("k", &[42u8; 8192]).unwrap();
        let t0 = Instant::now();
        let got = aio.submit_read("k".into(), vec![0u8; 8192]).wait().unwrap();
        let waited = t0.elapsed();
        assert!(got.iter().all(|&b| b == 42), "hedged read returned wrong bytes");
        assert!(
            waited < Duration::from_millis(300),
            "hedge did not cut the stall: waited {waited:?}"
        );
        let health = aio.executor().health();
        assert_eq!(health.hedges(), 1, "exactly one hedge fired");
        assert_eq!(health.timeouts(), 1, "the stall was recorded as a timeout");
        // the stalled primary still completes and is recorded; give it
        // time so the temp dir is not yanked from under it
        std::thread::sleep(Duration::from_millis(450));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fast_or_prefetched_reads_never_hedge() {
        let dir = std::env::temp_dir().join(format!("ma-nohedge-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let inner: Arc<dyn NvmeEngine> =
            Arc::new(DirectEngine::new(&dir, 1, 1 << 22, 1).unwrap());
        let aio = AsyncEngine::new(inner, 2)
            .with_deadline(Some(Duration::from_millis(1)));
        aio.write("k", &[7u8; 1024]).unwrap();
        // prefetch shape: the handle resolves long before the wait, so
        // even a 1 ms deadline must not hedge (the clock starts at wait)
        let h = aio.submit_read("k".into(), vec![0u8; 1024]);
        std::thread::sleep(Duration::from_millis(60));
        assert!(h.is_ready());
        let got = h.wait().unwrap();
        assert!(got.iter().all(|&b| b == 7));
        let health = aio.executor().health();
        assert_eq!(health.hedges(), 0);
        assert_eq!(health.timeouts(), 0);
        assert!(health.ops() >= 2, "writes and reads both feed health");
        // an error from a hedged submission surfaces as an error
        assert!(aio.submit_read("missing".into(), vec![0u8; 8]).wait().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
