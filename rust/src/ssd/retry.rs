//! Bounded retry with exponential backoff for transient I/O faults.
//!
//! SSD-offloaded training pushes every optimizer byte through the
//! engine each step, so a single transient EIO (link reset, thermal
//! throttle hiccup, injected fault) would otherwise kill a multi-hour
//! run.  [`RetryEngine`] wraps any [`NvmeEngine`] and retries each
//! failing operation up to [`RetryPolicy::max_attempts`] times with
//! exponential backoff; the async layer's submit paths
//! ([`crate::ssd::queue::AsyncEngine`]) call through the wrapped
//! engine, so swapper fetches, tiled write-backs, and flush barriers
//! all inherit the retry behavior from this one seam.
//!
//! Retries are *metered*, not silent: every repeated attempt bumps the
//! counter surfaced as [`IoSnapshot::retries`], which the trainer
//! reports per step (`StepMetrics::io_retries`).  Exhaustion is a
//! *distinct* failure class: [`RetryEngine`] wraps the last error in
//! [`RetryExhausted`] — carrying the op kind, the key, and the
//! attempt count — and charges [`IoSnapshot::retry_exhaustions`]
//! separately from transient retries, so dashboards can tell "the
//! backoff absorbed a blip" from "an op died for good".  Permanent
//! errors (missing key, out-of-bounds range) are retried too — the
//! engine cannot distinguish fault classes portably — but the bounded
//! policy caps the added latency at `max_attempts - 1` backoffs.
//!
//! Backoff delays carry deterministic pseudo-random **jitter**
//! ([`RetryPolicy::jitter_pct`]) so many queue workers retrying the
//! same thermal hiccup don't re-converge on the device in lockstep.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::faulty::OpKind;
use super::{IoSnapshot, NvmeEngine};
use crate::util::events::JobId;

/// Retry budget + backoff schedule.  Delay before attempt `k` (1-based
/// retries) is `base_delay * 2^(k-1)`, capped at `max_delay`, plus up
/// to `jitter_pct` percent of that value (deterministic per-attempt
/// hash, so tests stay reproducible).
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Total attempts per op (first try included).  `<= 1` disables
    /// retry.
    pub max_attempts: u32,
    pub base_delay: Duration,
    pub max_delay: Duration,
    /// Jitter ceiling as a percentage of the capped backoff delay
    /// (0 = the old fully-deterministic schedule).
    pub jitter_pct: u32,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_attempts: 3,
            base_delay: Duration::from_micros(500),
            max_delay: Duration::from_millis(50),
            jitter_pct: 25,
        }
    }
}

impl RetryPolicy {
    /// Policy for `attempts` total attempts with the default backoff.
    pub fn attempts(attempts: u32) -> Self {
        Self { max_attempts: attempts.max(1), ..Default::default() }
    }

    fn delay_for(&self, retry_idx: u32) -> Duration {
        let factor = 1u32 << retry_idx.min(16);
        (self.base_delay * factor).min(self.max_delay)
    }

    /// `delay_for` plus the salted jitter share: `salt` is hashed
    /// (splitmix-style) to a fraction of [0, 1) scaling `jitter_pct`
    /// percent of the base delay.
    fn delay_with_jitter(&self, retry_idx: u32, salt: u64) -> Duration {
        let base = self.delay_for(retry_idx);
        if self.jitter_pct == 0 {
            return base;
        }
        let mut z = salt.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        let frac = (z >> 11) as f64 / (1u64 << 53) as f64;
        base + base.mul_f64(frac * self.jitter_pct as f64 / 100.0)
    }
}

/// Terminal retry failure: `policy.max_attempts` tries of one
/// operation all failed.  Carries what died (op kind + key + attempt
/// count) and the final underlying error's message, so exhaustion can
/// be routed and alerted distinctly from absorbed transient faults.
#[derive(Debug)]
pub struct RetryExhausted {
    pub op: OpKind,
    pub key: String,
    pub attempts: u32,
    /// Display of the last underlying error.
    pub last: String,
}

impl std::fmt::Display for RetryExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "retry exhausted after {} attempts: {} on '{}': {}",
            self.attempts,
            self.op.name(),
            self.key,
            self.last
        )
    }
}

impl std::error::Error for RetryExhausted {}

/// Run `op` under `policy`, charging each repeat to `retries`.
/// Returns the first success or the last error once attempts are
/// exhausted.  Free-function form for callers outside an engine stack
/// (no op-kind context, so no [`RetryExhausted`] wrapping).
pub fn with_retry<T>(
    policy: &RetryPolicy,
    retries: &AtomicU64,
    mut op: impl FnMut() -> anyhow::Result<T>,
) -> anyhow::Result<T> {
    let attempts = policy.max_attempts.max(1);
    let mut last = None;
    for i in 0..attempts {
        if i > 0 {
            retries.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(policy.delay_for(i - 1));
        }
        match op() {
            Ok(v) => return Ok(v),
            Err(e) => last = Some(e),
        }
    }
    Err(last.expect("attempts >= 1"))
}

/// [`NvmeEngine`] decorator applying [`RetryPolicy`] to every
/// operation.  Sits *below* [`crate::ssd::queue::AsyncEngine`] in the
/// offload engine's stack, so synchronous calls and queued submit
/// closures retry identically.
pub struct RetryEngine {
    inner: Arc<dyn NvmeEngine>,
    policy: RetryPolicy,
    retries: AtomicU64,
    exhaustions: AtomicU64,
    /// Monotone salt feeding the per-attempt jitter hash.
    salt: AtomicU64,
    /// Tenant whose lane the retry/exhaustion counters charge in
    /// [`IoSnapshot::job_retries`] / [`IoSnapshot::job_retry_exhaustions`]
    /// — per-job views set this so fault absorption attributes to
    /// tenants the way ops/bytes already do.
    job: JobId,
}

impl RetryEngine {
    pub fn new(inner: Arc<dyn NvmeEngine>, policy: RetryPolicy) -> Self {
        Self {
            inner,
            policy,
            retries: AtomicU64::new(0),
            exhaustions: AtomicU64::new(0),
            salt: AtomicU64::new(0),
            job: JobId::HOST,
        }
    }

    /// Attribute this engine's retry counters to `job`'s snapshot lane.
    pub fn for_job(mut self, job: JobId) -> Self {
        self.job = job;
        self
    }

    /// Retries performed so far (also folded into
    /// [`IoSnapshot::retries`]).
    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    /// Ops whose whole retry budget failed (also folded into
    /// [`IoSnapshot::retry_exhaustions`]).
    pub fn exhaustions(&self) -> u64 {
        self.exhaustions.load(Ordering::Relaxed)
    }

    /// The engine-op retry loop: jittered backoff between attempts,
    /// [`RetryExhausted`] (op kind + key + attempt count) once the
    /// budget is gone.
    fn run<T>(
        &self,
        op: OpKind,
        key: &str,
        mut f: impl FnMut() -> anyhow::Result<T>,
    ) -> anyhow::Result<T> {
        let attempts = self.policy.max_attempts.max(1);
        let mut last = None;
        for i in 0..attempts {
            if i > 0 {
                self.retries.fetch_add(1, Ordering::Relaxed);
                let salt = self.salt.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(self.policy.delay_with_jitter(i - 1, salt));
            }
            match f() {
                Ok(v) => return Ok(v),
                Err(e) => last = Some(e),
            }
        }
        self.exhaustions.fetch_add(1, Ordering::Relaxed);
        Err(RetryExhausted {
            op,
            key: key.to_string(),
            attempts,
            last: last.expect("attempts >= 1").to_string(),
        }
        .into())
    }
}

impl NvmeEngine for RetryEngine {
    fn write(&self, key: &str, data: &[u8]) -> anyhow::Result<()> {
        self.run(OpKind::Write, key, || self.inner.write(key, data))
    }

    fn read(&self, key: &str, out: &mut [u8]) -> anyhow::Result<()> {
        self.run(OpKind::Read, key, || self.inner.read(key, out))
    }

    fn read_at(&self, key: &str, offset: usize, out: &mut [u8]) -> anyhow::Result<()> {
        self.run(OpKind::ReadAt, key, || self.inner.read_at(key, offset, out))
    }

    fn write_at(&self, key: &str, offset: usize, data: &[u8]) -> anyhow::Result<()> {
        self.run(OpKind::WriteAt, key, || self.inner.write_at(key, offset, data))
    }

    fn flush(&self, key: &str) -> anyhow::Result<()> {
        self.run(OpKind::Flush, key, || self.inner.flush(key))
    }

    fn reserve(&self, key: &str, len: usize) -> anyhow::Result<()> {
        self.run(OpKind::Reserve, key, || self.inner.reserve(key, len))
    }

    fn len_of(&self, key: &str) -> Option<usize> {
        self.inner.len_of(key)
    }

    fn stats(&self) -> IoSnapshot {
        let mut s = self.inner.stats();
        s.retries += self.retries();
        s.retry_exhaustions += self.exhaustions();
        s.job_retries[self.job.lane()] += self.retries();
        s.job_retry_exhaustions[self.job.lane()] += self.exhaustions();
        s
    }

    fn label(&self) -> &'static str {
        self.inner.label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssd::faulty::{FaultyEngine, OpMask};
    use crate::ssd::DirectEngine;

    fn direct(tag: &str) -> (Arc<dyn NvmeEngine>, std::path::PathBuf) {
        let dir =
            std::env::temp_dir().join(format!("ma-retry-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let e: Arc<dyn NvmeEngine> =
            Arc::new(DirectEngine::new(&dir, 1, 1 << 22, 1).unwrap());
        (e, dir)
    }

    #[test]
    fn transient_faults_absorbed_and_metered() {
        let (inner, dir) = direct("tr");
        // every op fails twice, then succeeds; 3 attempts cover it
        let faulty = Arc::new(FaultyEngine::transient(inner, 2, OpMask::ALL));
        let eng = RetryEngine::new(faulty.clone(), RetryPolicy::attempts(3));
        eng.write("k", &[7u8; 256]).unwrap();
        let mut out = [0u8; 256];
        eng.read("k", &mut out).unwrap();
        assert_eq!(out, [7u8; 256]);
        eng.flush("k").unwrap();
        // write: 2 retries, read: 2, flush: 2
        assert_eq!(eng.retries(), 6);
        assert_eq!(eng.stats().retries, 6);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn exhaustion_surfaces_typed_error_and_is_metered() {
        let (inner, dir) = direct("ex");
        // fails 5 times per op; 3 attempts are not enough
        let faulty = Arc::new(FaultyEngine::transient(inner, 5, OpMask::ALL));
        let eng = RetryEngine::new(faulty, RetryPolicy::attempts(3));
        let err = eng.write("k", &[1u8; 64]).unwrap_err();
        // the underlying error's message survives inside the wrapper
        assert!(err.to_string().contains("injected"), "{err}");
        assert!(err.to_string().contains("retry exhausted"), "{err}");
        let ex = err.downcast_ref::<RetryExhausted>().expect("typed exhaustion");
        assert_eq!(ex.op, OpKind::Write);
        assert_eq!(ex.key, "k");
        assert_eq!(ex.attempts, 3);
        assert_eq!(eng.retries(), 2, "both retries charged");
        assert_eq!(eng.exhaustions(), 1, "one op died for good");
        assert_eq!(eng.stats().retry_exhaustions, 1);
        // a later absorbed fault must not bump exhaustions again
        let mut out = [0u8; 64];
        assert!(eng.read("k", &mut out).is_err()); // 5-fail budget continues
        assert_eq!(eng.exhaustions(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn retries_attribute_to_the_owning_job_lane() {
        let (inner, dir) = direct("lane");
        let faulty = Arc::new(FaultyEngine::transient(inner, 2, OpMask::ALL));
        let eng =
            RetryEngine::new(faulty, RetryPolicy::attempts(3)).for_job(JobId(3));
        eng.write("k", &[9u8; 128]).unwrap();
        let s = eng.stats();
        assert_eq!(s.retries, 2);
        assert_eq!(s.job_retries[JobId(3).lane()], 2);
        assert_eq!(s.job_retries[JobId::HOST.lane()], 0);
        assert_eq!(s.job_retry_exhaustions[JobId(3).lane()], 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn single_attempt_policy_never_retries() {
        let (inner, dir) = direct("one");
        let faulty = Arc::new(FaultyEngine::transient(inner, 1, OpMask::ALL));
        let eng = RetryEngine::new(faulty, RetryPolicy::attempts(1));
        assert!(eng.write("k", &[0u8; 16]).is_err());
        assert_eq!(eng.retries(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fault_free_path_is_transparent() {
        let (inner, dir) = direct("ok");
        let eng = RetryEngine::new(inner, RetryPolicy::default());
        eng.write("k", &[3u8; 128]).unwrap();
        eng.reserve("r", 4096).unwrap();
        eng.write_at("r", 512, &[9u8; 64]).unwrap();
        let mut out = [0u8; 64];
        eng.read_at("r", 512, &mut out).unwrap();
        assert_eq!(out, [9u8; 64]);
        assert_eq!(eng.retries(), 0);
        assert_eq!(eng.len_of("k"), Some(128));
        assert_eq!(eng.label(), "direct-nvme");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn backoff_schedule_is_capped() {
        let p = RetryPolicy {
            max_attempts: 10,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(4),
            jitter_pct: 0,
        };
        assert_eq!(p.delay_for(0), Duration::from_millis(1));
        assert_eq!(p.delay_for(1), Duration::from_millis(2));
        assert_eq!(p.delay_for(2), Duration::from_millis(4));
        assert_eq!(p.delay_for(7), Duration::from_millis(4), "capped");
        // zero jitter: the jittered schedule is the plain one
        assert_eq!(p.delay_with_jitter(2, 123), Duration::from_millis(4));
    }

    #[test]
    fn jitter_stays_within_the_configured_share() {
        let p = RetryPolicy {
            max_attempts: 10,
            base_delay: Duration::from_millis(2),
            max_delay: Duration::from_millis(64),
            jitter_pct: 50,
        };
        let mut seen_spread = false;
        let mut first = None;
        for salt in 0..64u64 {
            let d = p.delay_with_jitter(1, salt); // base 4ms
            assert!(d >= Duration::from_millis(4), "jitter only adds: {d:?}");
            assert!(d <= Duration::from_millis(6), "<= base + 50%: {d:?}");
            match first {
                None => first = Some(d),
                Some(f) if f != d => seen_spread = true,
                _ => {}
            }
        }
        assert!(seen_spread, "64 salts must not all hash to one delay");
    }
}
