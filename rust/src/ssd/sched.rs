//! Deficit-weighted-round-robin submission scheduling.
//!
//! Under multi-job tenancy every [`super::queue::IoExecutor`]
//! submission carries a job lane and a byte cost; this module holds the
//! pure scheduling structure that replaces the old FIFO: a classic
//! **deficit round robin** (Shreedhar & Varghese) with per-flow
//! weights.
//!
//! - Each flow (job lane) keeps a FIFO of `(cost, item)` — order
//!   *within* a job is unchanged, which is what the swapper's reorder
//!   window and the optimizer's flush barriers rely on.
//! - An active ring visits backlogged flows round-robin.  On each
//!   fresh visit a flow earns `weight × QUANTUM_UNIT` deficit; it may
//!   dispatch head-of-line items while its deficit covers their cost,
//!   then the ring rotates.  Over any backlogged interval, served
//!   bytes converge to the weight ratio regardless of item sizes or
//!   arrival order.
//! - **Work conserving:** `pop` returns an item whenever any flow has
//!   one queued — an oversized head never idles the queue, because
//!   each rotation grows that flow's deficit until it covers the cost.
//! - A flow that drains leaves the ring and forfeits its leftover
//!   deficit (standard DRR: an idle job cannot bank priority).
//!
//! Costs are bytes for data transfers and `1` for control work
//! (flushes, metadata); a zero cost is clamped to one so control-only
//! floods still rotate fairly.

use std::collections::VecDeque;

/// Deficit earned per fresh ring visit, per unit of weight.  64 KiB —
/// comparable to one tile-sized transfer, so small-weight flows still
/// make progress every few rotations.
pub const QUANTUM_UNIT: u64 = 64 * 1024;

struct Flow<T> {
    q: VecDeque<(u64, T)>,
    weight: u32,
    deficit: u64,
    in_ring: bool,
    /// A fresh ring visit (first look since the flow entered the ring
    /// or since the ring last rotated past it) earns a quantum.
    fresh: bool,
}

impl<T> Flow<T> {
    fn new() -> Self {
        Self { q: VecDeque::new(), weight: 1, deficit: 0, in_ring: false, fresh: true }
    }

    fn quantum(&self) -> u64 {
        u64::from(self.weight.max(1)) * QUANTUM_UNIT
    }
}

/// Weighted-fair multi-flow queue.  Flows are dense `usize` lanes
/// (see [`crate::util::events::JobId::lane`]); unknown lanes are
/// created on first touch with weight 1, so the single-job case is
/// plain FIFO with zero configuration.
pub struct DwrrQueue<T> {
    flows: Vec<Flow<T>>,
    ring: VecDeque<usize>,
    len: usize,
}

impl<T> Default for DwrrQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> DwrrQueue<T> {
    pub fn new() -> Self {
        Self { flows: Vec::new(), ring: VecDeque::new(), len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Queued items on one lane.
    pub fn lane_len(&self, lane: usize) -> usize {
        self.flows.get(lane).map_or(0, |f| f.q.len())
    }

    fn ensure(&mut self, lane: usize) {
        while self.flows.len() <= lane {
            self.flows.push(Flow::new());
        }
    }

    /// Set a lane's scheduling weight (clamped to ≥ 1).  Takes effect
    /// on the lane's next fresh ring visit.
    pub fn set_weight(&mut self, lane: usize, weight: u32) {
        self.ensure(lane);
        self.flows[lane].weight = weight.max(1);
    }

    pub fn weight(&self, lane: usize) -> u32 {
        self.flows.get(lane).map_or(1, |f| f.weight)
    }

    /// Enqueue `item` on `lane` with a byte `cost` (clamped to ≥ 1).
    pub fn push(&mut self, lane: usize, cost: u64, item: T) {
        self.ensure(lane);
        let flow = &mut self.flows[lane];
        flow.q.push_back((cost.max(1), item));
        self.len += 1;
        if !flow.in_ring {
            flow.in_ring = true;
            flow.fresh = true;
            flow.deficit = 0;
            self.ring.push_back(lane);
        }
    }

    /// Dispatch the next item under the weighted-fair policy; returns
    /// `(lane, cost, item)`.  `Some` whenever `len() > 0` (work
    /// conservation); `None` only on an empty queue.
    pub fn pop(&mut self) -> Option<(usize, u64, T)> {
        if self.len == 0 {
            return None;
        }
        loop {
            let lane = *self.ring.front().expect("non-empty queue has a ring");
            let flow = &mut self.flows[lane];
            if flow.fresh {
                flow.deficit = flow.deficit.saturating_add(flow.quantum());
                flow.fresh = false;
            }
            let head_cost = flow.q.front().map(|(c, _)| *c).expect("ringed flow has work");
            if head_cost <= flow.deficit {
                flow.deficit -= head_cost;
                let (cost, item) = flow.q.pop_front().expect("checked above");
                self.len -= 1;
                if flow.q.is_empty() {
                    // drained flows forfeit leftover deficit and leave
                    // the ring — idle jobs cannot bank priority
                    flow.deficit = 0;
                    flow.in_ring = false;
                    self.ring.pop_front();
                }
                return Some((lane, cost, item));
            }
            // deficit doesn't cover the head: rotate.  The flow earns
            // another quantum on its next visit, so any finite cost is
            // eventually covered and `pop` terminates.
            flow.fresh = true;
            let lane = self.ring.pop_front().expect("checked above");
            self.ring.push_back(lane);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::{check, Config};
    use crate::util::rng::Xoshiro256;

    #[test]
    fn single_lane_is_fifo() {
        let mut q = DwrrQueue::new();
        for i in 0..100u32 {
            q.push(0, 1 + (i as u64 % 7) * 4096, i);
        }
        for i in 0..100u32 {
            let (lane, _, item) = q.pop().unwrap();
            assert_eq!((lane, item), (0, i));
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn order_within_each_lane_is_preserved() {
        let mut q = DwrrQueue::new();
        for i in 0..40u32 {
            q.push((i % 3) as usize, 8192, i);
        }
        let mut last = [None::<u32>; 3];
        while let Some((lane, _, item)) = q.pop() {
            if let Some(prev) = last[lane] {
                assert!(item > prev, "lane {lane} reordered: {prev} then {item}");
            }
            last[lane] = Some(item);
        }
    }

    #[test]
    fn oversized_head_dispatches_instead_of_idling() {
        let mut q = DwrrQueue::new();
        // cost far beyond one quantum: deficit must accumulate across
        // rotations rather than wedge the queue
        q.push(0, 400 * QUANTUM_UNIT, "huge");
        q.push(1, 1, "tiny");
        let mut seen = Vec::new();
        while let Some((_, _, item)) = q.pop() {
            seen.push(item);
        }
        assert_eq!(seen.len(), 2);
        assert!(seen.contains(&"huge") && seen.contains(&"tiny"));
    }

    #[test]
    fn idle_lane_does_not_bank_deficit() {
        let mut q = DwrrQueue::new();
        q.set_weight(0, 8);
        // lane 0 drains completely (forfeiting its deficit), then both
        // lanes get equal-cost backlogs: lane 0's advantage must come
        // only from its weight, not from banked idle time
        q.push(0, 1, 0u32);
        assert!(q.pop().is_some());
        for i in 0..32 {
            q.push(0, QUANTUM_UNIT, i);
            q.push(1, QUANTUM_UNIT, 100 + i);
        }
        let mut served = [0u64; 2];
        for _ in 0..18 {
            let (lane, cost, _) = q.pop().unwrap();
            served[lane] += cost;
        }
        // weight 8:1 over 18 equal-cost items -> lane 0 gets 16, lane 1
        // gets 2 (one quantum each per rotation)
        assert!(served[0] >= 14 * QUANTUM_UNIT, "lane0 served {}", served[0]);
        assert!(served[1] >= QUANTUM_UNIT, "lane1 starved");
    }

    /// Satellite: work conservation — `pop` yields an item whenever
    /// any lane has queued submissions, across random interleavings of
    /// pushes and pops on random lanes/weights/costs.
    #[test]
    fn prop_work_conservation() {
        check("dwrr-work-conservation", Config::default(), |rng, size| {
            let lanes = 1 + rng.below(8);
            let mut q = DwrrQueue::new();
            for l in 0..lanes {
                q.set_weight(l, 1 + rng.below(16) as u32);
            }
            let mut pushed = 0u64;
            let mut popped = 0u64;
            let ops = size.max(16);
            for _ in 0..ops {
                if rng.below(2) == 0 {
                    let lane = rng.below(lanes);
                    let cost = rng.below(256 * 1024) as u64; // 0 gets clamped
                    q.push(lane, cost, pushed);
                    pushed += 1;
                } else {
                    let backlog = q.len();
                    match q.pop() {
                        Some(_) => {
                            prop_assert!(backlog > 0, "pop produced from empty queue");
                            popped += 1;
                        }
                        None => {
                            prop_assert!(
                                backlog == 0,
                                "queue idled with {backlog} queued submissions"
                            );
                        }
                    }
                }
                prop_assert!(
                    q.len() as u64 == pushed - popped,
                    "len {} != pushed {pushed} - popped {popped}",
                    q.len()
                );
            }
            // drain: every queued item must come out, exactly once
            while q.pop().is_some() {
                popped += 1;
            }
            prop_assert!(popped == pushed, "drained {popped} of {pushed}");
            Ok(())
        });
    }

    /// Satellite: proportional share convergence — over a continuously
    /// backlogged interval, each lane's served bytes track its weight
    /// fraction, across random weight vectors and arrival patterns.
    #[test]
    fn prop_proportional_share_convergence() {
        check(
            "dwrr-proportional-share",
            Config { cases: 48, ..Default::default() },
            |rng, _size| {
                let lanes = 2 + rng.below(5);
                let weights: Vec<u32> =
                    (0..lanes).map(|_| 1 + rng.below(8) as u32).collect();
                let wsum: u64 = weights.iter().map(|&w| u64::from(w)).sum();
                let mut q = DwrrQueue::new();
                for (l, &w) in weights.iter().enumerate() {
                    q.set_weight(l, w);
                }
                // every lane gets a backlog far deeper than the service
                // interval, with randomized item sizes and interleaved
                // arrival order
                let backlog_bytes: u64 = 64 << 20;
                let serve_bytes: u64 = 16 << 20;
                let mut remaining: Vec<u64> = vec![backlog_bytes; lanes];
                let mut order: Vec<usize> = (0..lanes).collect();
                for i in (1..order.len()).rev() {
                    order.swap(i, rng.below(i + 1));
                }
                for &l in &order {
                    while remaining[l] > 0 {
                        let cost =
                            (1 + rng.below(128 * 1024) as u64).min(remaining[l]);
                        remaining[l] -= cost;
                        q.push(l, cost, ());
                    }
                }
                let mut served = vec![0u64; lanes];
                let mut total = 0u64;
                while total < serve_bytes {
                    let (lane, cost, ()) = q.pop().expect("deep backlog");
                    served[lane] += cost;
                    total += cost;
                }
                // no lane ran dry (served ≤ total « backlog), so the
                // whole interval was continuously backlogged
                for (l, &got) in served.iter().enumerate() {
                    prop_assert!(got < backlog_bytes, "lane {l} ran dry mid-interval");
                    let want = total as f64 * f64::from(weights[l]) / wsum as f64;
                    let err = (got as f64 - want).abs() / want;
                    prop_assert!(
                        err < 0.10,
                        "lane {l} (w={}) served {got} of {total}, want ~{want:.0} \
                         ({:.1}% off; weights {weights:?})",
                        weights[l],
                        err * 100.0
                    );
                }
                Ok(())
            },
        );
    }
}
