//! Parameter tensor inventory — the ground truth every subsystem shares.
//!
//! The paper's memory analysis is entirely shape-driven: the buffer
//! pool fragments because the embedding tensor (vocab × hidden) dwarfs
//! the per-block projections; the adaptive pool wins by grouping
//! tensors into the four shape classes of §IV-B.  This module
//! enumerates every parameter tensor of a `ModelSpec` with its exact
//! shape, category, and shape class, in the canonical offload order the
//! trainer and the accounting engine both walk.

use crate::config::ModelSpec;
use crate::dtype::DType;

/// Semantic category (drives Fig. 11's pool sizing and Fig. 2's bars).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    Embedding,
    LmHead,
    AttnQ,
    AttnK,
    AttnV,
    AttnO,
    FfnGate,
    FfnUp,
    FfnDown,
    Router,
    ExpertGate,
    ExpertUp,
    ExpertDown,
    Norm,
}

/// Buffer-pool shape class (paper §IV-B: "four pools are sufficient" for
/// dense models — embedding-, feed-forward-, KV-, and QO-shaped; MoE
/// adds an expert class; sub-2M-element tensors stay CPU-resident).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ShapeClass {
    Embed,
    Ffn,
    Kv,
    Qo,
    Expert,
    /// Small tensors (norms, routers): never offloaded to SSD
    /// (paper §VI-B-1c: "<2M elements perform better in CPU memory").
    Resident,
}

/// Paper threshold: tensors below this stay resident in system memory.
pub const OFFLOAD_THRESHOLD_ELEMS: usize = 2_000_000;

#[derive(Debug, Clone)]
pub struct TensorDesc {
    /// e.g. "layers.3.wq", "embed", "lm_head".
    pub name: String,
    pub shape: Vec<usize>,
    pub category: Category,
    /// Layer index, or usize::MAX for embed/head/final norm.
    pub layer: usize,
    pub numel: usize,
}

impl TensorDesc {
    fn new(name: String, shape: Vec<usize>, category: Category, layer: usize) -> Self {
        let numel = shape.iter().product();
        Self { name, shape, category, layer, numel }
    }

    pub fn bytes(&self, dtype: DType) -> usize {
        self.numel * dtype.size()
    }

    pub fn shape_class(&self) -> ShapeClass {
        match self.category {
            Category::Embedding | Category::LmHead => ShapeClass::Embed,
            Category::FfnGate | Category::FfnUp | Category::FfnDown => ShapeClass::Ffn,
            Category::AttnK | Category::AttnV => ShapeClass::Kv,
            Category::AttnQ | Category::AttnO => ShapeClass::Qo,
            Category::ExpertGate | Category::ExpertUp | Category::ExpertDown => {
                ShapeClass::Expert
            }
            Category::Norm | Category::Router => ShapeClass::Resident,
        }
    }

    pub fn offloadable(&self) -> bool {
        self.shape_class() != ShapeClass::Resident
    }
}

/// Enumerate every parameter tensor in canonical offload order:
/// embed, then each layer's weights in forward order, final norm, head.
pub fn inventory(spec: &ModelSpec) -> Vec<TensorDesc> {
    let (h, kv) = (spec.hidden, spec.kv_dim());
    let mut out = Vec::new();
    out.push(TensorDesc::new(
        "embed".into(),
        vec![spec.vocab, h],
        Category::Embedding,
        usize::MAX,
    ));
    for l in 0..spec.layers {
        let p = |n: &str| format!("layers.{l}.{n}");
        out.push(TensorDesc::new(p("attn_norm"), vec![h], Category::Norm, l));
        out.push(TensorDesc::new(p("wq"), vec![h, h], Category::AttnQ, l));
        out.push(TensorDesc::new(p("wk"), vec![h, kv], Category::AttnK, l));
        out.push(TensorDesc::new(p("wv"), vec![h, kv], Category::AttnV, l));
        out.push(TensorDesc::new(p("wo"), vec![h, h], Category::AttnO, l));
        out.push(TensorDesc::new(p("ffn_norm"), vec![h], Category::Norm, l));
        if spec.is_moe() {
            let fe = spec.expert_intermediate;
            out.push(TensorDesc::new(
                p("router"),
                vec![h, spec.n_experts],
                Category::Router,
                l,
            ));
            for e in 0..spec.n_experts {
                let ep = |n: &str| format!("layers.{l}.experts.{e}.{n}");
                out.push(TensorDesc::new(
                    ep("w_gate"),
                    vec![h, fe],
                    Category::ExpertGate,
                    l,
                ));
                out.push(TensorDesc::new(
                    ep("w_up"),
                    vec![h, fe],
                    Category::ExpertUp,
                    l,
                ));
                out.push(TensorDesc::new(
                    ep("w_down"),
                    vec![fe, h],
                    Category::ExpertDown,
                    l,
                ));
            }
        } else {
            let f = spec.intermediate;
            out.push(TensorDesc::new(p("w_gate"), vec![h, f], Category::FfnGate, l));
            out.push(TensorDesc::new(p("w_up"), vec![h, f], Category::FfnUp, l));
            out.push(TensorDesc::new(p("w_down"), vec![f, h], Category::FfnDown, l));
        }
    }
    out.push(TensorDesc::new(
        "final_norm".into(),
        vec![h],
        Category::Norm,
        usize::MAX,
    ));
    if !spec.tie_embeddings {
        out.push(TensorDesc::new(
            "lm_head".into(),
            vec![h, spec.vocab],
            Category::LmHead,
            usize::MAX,
        ));
    }
    out
}

/// Largest offloadable tensor size in elements — what the monolithic
/// pool sizes *every* buffer to (the root of §III-A's fragmentation).
pub fn largest_offloadable_elems(spec: &ModelSpec) -> usize {
    inventory(spec)
        .iter()
        .filter(|t| t.offloadable())
        .map(|t| t.numel)
        .max()
        .unwrap_or(0)
}

/// Per shape-class maximum element counts (what the adaptive pool sizes
/// each subpool's buffers to).
pub fn class_max_elems(spec: &ModelSpec) -> Vec<(ShapeClass, usize)> {
    let mut map = std::collections::BTreeMap::new();
    for t in inventory(spec) {
        let c = t.shape_class();
        if c == ShapeClass::Resident {
            continue;
        }
        let e = map.entry(c).or_insert(0usize);
        *e = (*e).max(t.numel);
    }
    map.into_iter().collect()
}

/// Offloadable tensors per transformer block, grouped by shape class —
/// determines subgroup counts per in-flight block (paper: 3N ffn,
/// 2N kv, 2N qo for dense; MoE: 3·E expert tensors per block).
pub fn class_counts_per_block(spec: &ModelSpec) -> Vec<(ShapeClass, usize)> {
    let mut map = std::collections::BTreeMap::new();
    for t in inventory(spec) {
        if t.layer != 0 {
            continue; // one representative block
        }
        let c = t.shape_class();
        if c == ShapeClass::Resident {
            continue;
        }
        *map.entry(c).or_insert(0usize) += 1;
    }
    map.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn dense_inventory_structure() {
        let inv = inventory(&presets::QWEN25_7B);
        // embed + 28*(2 norms + 4 attn + 3 ffn) + final norm + head
        assert_eq!(inv.len(), 1 + 28 * 9 + 2);
        assert_eq!(inv[0].category, Category::Embedding);
        assert_eq!(inv.last().unwrap().category, Category::LmHead);
    }

    #[test]
    fn embedding_is_largest() {
        for m in presets::PAPER_DENSE {
            let largest = largest_offloadable_elems(m);
            assert_eq!(largest, m.vocab * m.hidden, "{}", m.name);
        }
    }

    #[test]
    fn norms_are_resident() {
        let inv = inventory(&presets::QWEN25_7B);
        for t in &inv {
            if t.category == Category::Norm {
                assert_eq!(t.shape_class(), ShapeClass::Resident);
            }
        }
    }

    #[test]
    fn qwen7b_class_counts_match_paper() {
        // paper §IV-B: per-block subgroup counts 3 (ffn), 2 (kv), 2 (qo)
        let counts: std::collections::BTreeMap<_, _> =
            class_counts_per_block(&presets::QWEN25_7B).into_iter().collect();
        assert_eq!(counts.get(&ShapeClass::Ffn), Some(&3));
        assert_eq!(counts.get(&ShapeClass::Kv), Some(&2));
        assert_eq!(counts.get(&ShapeClass::Qo), Some(&2));
    }

    #[test]
    fn offload_threshold_is_a_benchmark_guideline_only() {
        // The NVMe benches pick tensor sizes above this threshold
        // (paper §VI-B-1c: "<2M elements perform better in CPU memory"),
        // but pool classification is categorical: Qwen2.5-7B's GQA kv
        // projection (3584 x 512 = 1.84M) still belongs to the Kv pool.
        let inv = inventory(&presets::QWEN25_7B);
        let kv_t = inv.iter().find(|t| t.category == Category::AttnK).unwrap();
        assert!(kv_t.numel < OFFLOAD_THRESHOLD_ELEMS);
        assert_eq!(kv_t.shape_class(), ShapeClass::Kv);
    }

    #[test]
    fn moe_inventory_has_experts() {
        let inv = inventory(&presets::QWEN3_30B_A3B);
        let experts = inv
            .iter()
            .filter(|t| matches!(t.category, Category::ExpertGate))
            .count();
        assert_eq!(experts, 48 * 128);
        // expert tensors are small (2048*768 = 1.57M < 2M) -> resident?
        // MoE experts sit right at the boundary; shape-class logic must
        // classify them consistently.
        let e = inv.iter().find(|t| t.category == Category::ExpertGate).unwrap();
        assert_eq!(e.numel, 2048 * 768);
    }

    #[test]
    fn moe_param_count() {
        let p = presets::QWEN3_30B_A3B.param_count();
        assert!((29.0e9..32.0e9).contains(&(p as f64)), "{p}");
    }

    #[test]
    fn bytes_scale_with_dtype() {
        let inv = inventory(&presets::SMOKE);
        let t = &inv[1];
        assert_eq!(t.bytes(DType::F32), 2 * t.bytes(DType::F16));
    }
}
