//! Synthetic fine-tuning corpus (the OpenWebText stand-in, DESIGN.md §1).
//!
//! A phrase-library generator: a fixed library of multi-token phrases
//! (zipfian token draws) is sampled into documents.  Within a phrase
//! the next token is deterministic, so a competent model drives loss
//! well below `ln(vocab)` within tens of steps — giving Fig. 19-style
//! convergence curves a visible slope — while phrase boundaries keep
//! irreducible entropy, like real text.  Fully deterministic by seed:
//! the baseline-vs-MemAscend parity test depends on identical batches.

use crate::util::rng::Xoshiro256;

pub struct Corpus {
    /// Phrase library: each phrase is a fixed token sequence.
    phrases: Vec<Vec<i32>>,
    vocab: usize,
    rng: Xoshiro256,
}

impl Corpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed);
        let n_phrases = 64.min(vocab / 4).max(4);
        let phrase_len = 8;
        let phrases = (0..n_phrases)
            .map(|_| {
                (0..phrase_len)
                    .map(|_| rng.zipf(vocab - 1, 1.3) as i32 + 1)
                    .collect()
            })
            .collect();
        Self { phrases, vocab, rng }
    }

    /// Next (tokens, labels) pair: labels are tokens shifted by one
    /// (causal LM targets). Shapes: [batch * seq].
    pub fn next_batch(&mut self, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut row = Vec::with_capacity(seq + 1);
            while row.len() <= seq {
                let p = &self.phrases[self.rng.below(self.phrases.len())];
                row.extend_from_slice(p);
            }
            row.truncate(seq + 1);
            tokens.push(row);
        }
        let labels = tokens
            .iter()
            .flat_map(|row| row[1..].iter().copied())
            .collect();
        let tokens = tokens
            .iter()
            .flat_map(|row| row[..seq].iter().copied())
            .collect();
        (tokens, labels)
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Data-loader cursor for checkpointing.  The phrase library is a
    /// pure function of (vocab, seed), so the generator state is the
    /// whole cursor: rebuild the corpus with the same seed, then
    /// [`Corpus::set_rng_state`] to continue the exact batch sequence.
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    pub fn set_rng_state(&mut self, s: [u64; 4]) {
        self.rng = Xoshiro256::from_state(s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Corpus::new(256, 7);
        let mut b = Corpus::new(256, 7);
        assert_eq!(a.next_batch(2, 32), b.next_batch(2, 32));
    }

    #[test]
    fn labels_are_shifted_tokens() {
        let mut c = Corpus::new(128, 3);
        let (t, l) = c.next_batch(1, 16);
        assert_eq!(t.len(), 16);
        assert_eq!(l.len(), 16);
        // within the same row, label[i] should equal token[i+1]
        for i in 0..15 {
            assert_eq!(l[i], t[i + 1]);
        }
    }

    #[test]
    fn tokens_in_range() {
        let mut c = Corpus::new(64, 1);
        let (t, l) = c.next_batch(4, 64);
        assert!(t.iter().chain(&l).all(|&x| (1..64).contains(&(x as usize))));
    }

    #[test]
    fn corpus_is_predictable() {
        // phrase structure => conditional entropy far below ln(V):
        // measure bigram determinism
        let mut c = Corpus::new(512, 9);
        let (t, _) = c.next_batch(8, 256);
        let mut follows: std::collections::HashMap<i32, std::collections::HashMap<i32, usize>> =
            Default::default();
        for w in t.windows(2) {
            *follows.entry(w[0]).or_default().entry(w[1]).or_insert(0) += 1;
        }
        // majority successor frequency should dominate
        let mut dominant = 0usize;
        let mut total = 0usize;
        for (_, m) in follows {
            let sum: usize = m.values().sum();
            let max = *m.values().max().unwrap();
            dominant += max;
            total += sum;
        }
        assert!(
            dominant as f64 / total as f64 > 0.5,
            "corpus not predictable enough: {}",
            dominant as f64 / total as f64
        );
    }
}
