//! Pressure-adaptive pipeline governor: the feedback loop that turns
//! the trainer's static window knobs into a control system.
//!
//! PR 3 and PR 4 built the *mechanisms* — budget-enforced pinned
//! leases, a tile-granular optimizer pipeline, zero-copy delivery
//! views — but left every knob static.  Under a tight
//! `pinned_budget_bytes` the arena then silently degrades the hot
//! paths (`StepMetrics::host_copy_bytes > 0` on the boundary,
//! `degraded_tiles > 0` in the optimizer) instead of the pipeline
//! adapting; on an idle device the windows stay shallow and leave
//! bandwidth on the table.  SSDTrain's rate-matched transfers and
//! 10Cache's pressure-driven placement both argue the same point: the
//! window sizes should be *outputs* of observed pressure, not inputs.
//!
//! [`PipelineGovernor`] closes the loop over **five knobs**: the
//! optimizer tile size and pipeline depth, the swapper's prefetch
//! window, the replayed prefetch schedule's lead-time
//! (`sched_lead_us`), and the activation store's host byte budget
//! (`act_host_budget`).  Once per step the trainer feeds it a
//! [`GovernorSample`] — the arena's reserved/budget state
//! ([`crate::pinned::PinnedArena::stats`]), the boundary copy meter,
//! the optimizer's degraded-tile count, the swapper's prefetch
//! hit/late counts, and the step's stall/busy decomposition
//! (`io_wait_secs` vs the engine's union-of-busy `io_secs`) — and gets
//! back a clamped [`PipelineTuning`]:
//!
//! - **Pressure ⇒ shrink, immediately.**  `degraded_tiles > 0` means
//!   the optimizer window no longer fits the budget: halve the tile
//!   size, then step the tile depth down.  `host_copy_bytes > 0` means
//!   delivery staging is being refused: shallow the prefetch window
//!   first (fewer concurrent delivery views), then pull the replay
//!   schedule's lead-time in (later fetches hold staging leases for
//!   less wall time).  Past those, the activation host budget halves
//!   toward its floor — trading spill I/O for pinned headroom — before
//!   the governor gives up.  Every shrink is strictly monotone, so
//!   under persistent pressure the tuning reaches the configured
//!   minima in a *bounded* number of steps — convergence is a
//!   structural property, not a hope (tested).
//! - **Idle + stalls ⇒ grow, carefully.**  With zero pressure, stalls
//!   above [`GovernorConfig::grow_stall_frac`] and the queues not
//!   saturated, the governor deepens one knob per
//!   [`GovernorConfig::grow_cooldown_steps`] (round-robin over tile
//!   depth, tile bytes, prefetch depth, and the activation budget),
//!   and only when the projected extra pinned demand fits the arena's
//!   remaining budget headroom.  Knobs that previously *caused*
//!   pressure are remembered as ceilings and not re-approached until a
//!   long pressure-free stretch ([`GovernorConfig::reprobe_after`])
//!   clears them — hysteresis against shrink/grow ping-pong.
//! - **Late prefetches ⇒ more lead, targeted.**  The recorded-schedule
//!   replayer (see `offload/swapper.rs`) reports per-unit hit/late
//!   counts.  `prefetch_late > 0` without pressure means the schedule
//!   is cutting deadlines too fine: the lead-time doubles (under the
//!   same grow cooldown) up to [`GovernorConfig::max_lead_us`].  This
//!   is the arbitration the replay contract needs — arena pressure
//!   pulls lead-time *down* (shrink chain), late arrivals push it
//!   *up*, and the depth window bounds the damage of either extreme.
//!
//! Every retune is bit-identity-safe by construction: all five knobs
//! only reorder I/O over disjoint ranges or move activation bytes
//! between host and SSD tiers (the drivers' invariant), so the
//! governor can never change a trajectory — only its speed and memory
//! footprint.  `governor: false` in [`crate::config::TrainSpec`] pins
//! the initial tuning forever: exactly today's static behavior, byte
//! for byte.

/// Clamp bounds and control-law constants of the governor.
#[derive(Debug, Clone)]
pub struct GovernorConfig {
    pub min_tile_bytes: usize,
    pub max_tile_bytes: usize,
    pub min_tile_depth: usize,
    pub max_tile_depth: usize,
    pub min_prefetch_depth: usize,
    pub max_prefetch_depth: usize,
    /// Bounds for the replayed prefetch schedule's lead-time.
    pub min_lead_us: u64,
    pub max_lead_us: u64,
    /// Bounds for the activation store's host byte budget.  The
    /// trainer derives these from the configured `act_host_budget`
    /// (floor = an eighth of it), so an ungoverned run is unchanged.
    pub min_act_budget: usize,
    pub max_act_budget: usize,
    /// Grow only when the step stalled on I/O for more than this
    /// fraction of its wall time.
    pub grow_stall_frac: f64,
    /// Grow only when the engine-busy fraction is below this (queues
    /// have headroom; deepening can still help).
    pub busy_saturation_frac: f64,
    /// Steps between grow actions (shrinks are immediate).
    pub grow_cooldown_steps: u64,
    /// Pressure-free steps after which pressure ceilings are cleared
    /// and the governor may re-probe larger windows.
    pub reprobe_after: u64,
}

impl Default for GovernorConfig {
    fn default() -> Self {
        Self {
            min_tile_bytes: 64 << 10,
            max_tile_bytes: 64 << 20,
            min_tile_depth: 1,
            max_tile_depth: 8,
            min_prefetch_depth: 1,
            max_prefetch_depth: 8,
            min_lead_us: 200,
            max_lead_us: 200_000,
            min_act_budget: 0,
            max_act_budget: usize::MAX,
            grow_stall_frac: 0.05,
            busy_saturation_frac: 0.90,
            grow_cooldown_steps: 2,
            reprobe_after: 64,
        }
    }
}

/// The five pipeline knobs the governor owns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PipelineTuning {
    /// Optimizer tile size in state bytes (`step_groups_tiled` /
    /// `CoalescedOptim::step_tiled`).
    pub optim_tile_bytes: usize,
    /// Tile-pipeline window: fetch and write-back generations in
    /// flight (the dynamic replacement for the old
    /// `TILE_PIPELINE_DEPTH` constant).
    pub tile_depth: usize,
    /// Swapper fetch units kept in flight ahead of compute.
    pub prefetch_depth: usize,
    /// Safety lead subtracted from each replayed fetch deadline (µs);
    /// ignored by the depth-window path.
    pub sched_lead_us: u64,
    /// Host byte budget of the spilling activation store; bytes beyond
    /// it spill to SSD.
    pub act_host_budget: usize,
}

impl PipelineTuning {
    /// Worst-case pinned bytes the optimizer windows of this tuning
    /// keep in flight: `depth` fetch generations of 3 state tiles plus
    /// `depth` write-back generations of 3 state tiles + 1 fp16 tile.
    pub fn optim_window_bytes(&self) -> usize {
        self.optim_tile_bytes * self.tile_depth * 7
    }
}

/// Per-job ceilings a fleet-level arbiter may impose on top of one
/// job's governor (see `jobs::FleetGovernor`).  Caps overlay the
/// governor's own tuning at read time — they never mutate its internal
/// state, so lifting a cap restores exactly the windows the job's own
/// control law had converged to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FleetCaps {
    pub max_tile_depth: usize,
    pub max_prefetch_depth: usize,
    pub max_act_budget: usize,
}

impl FleetCaps {
    /// No ceiling on any knob (the identity overlay).
    pub fn unlimited() -> Self {
        Self {
            max_tile_depth: usize::MAX,
            max_prefetch_depth: usize::MAX,
            max_act_budget: usize::MAX,
        }
    }

    /// Apply these ceilings to a tuning.  Depth caps keep a floor of 1
    /// — a fleet can throttle a job to serial progress but never wedge
    /// it entirely.
    pub fn clamp(&self, t: PipelineTuning) -> PipelineTuning {
        PipelineTuning {
            tile_depth: t.tile_depth.min(self.max_tile_depth.max(1)),
            prefetch_depth: t.prefetch_depth.min(self.max_prefetch_depth.max(1)),
            act_host_budget: t.act_host_budget.min(self.max_act_budget),
            ..t
        }
    }
}

/// One step's observations, as the trainer sees them.
#[derive(Debug, Clone, Copy, Default)]
pub struct GovernorSample {
    /// fp32 bytes staged through owned heap buffers on the boundary
    /// path this step (`StepMetrics::host_copy_bytes`): non-zero means
    /// the arena refused delivery-view leases.
    pub host_copy_bytes: u64,
    /// Optimizer tiles degraded to the synchronous unpinned path this
    /// step (`PipelineStats::degraded_tiles`).
    pub degraded_tiles: u64,
    /// Fetch units compute blocked on this step
    /// (`SwapMetrics::prefetch_late`) — the replay schedule's
    /// lead-time grow signal.
    pub prefetch_late: u64,
    /// Fetch units already upconverted when compute asked
    /// (`SwapMetrics::prefetch_hits`).
    pub prefetch_hits: u64,
    /// Foreground I/O stall attributed to this step.
    pub io_wait_secs: f64,
    /// Engine-busy union for the step (`IoSnapshot::busy_ns` delta).
    pub io_busy_secs: f64,
    pub step_secs: f64,
    /// Arena bytes currently reserved (segments + pooled scratch).
    pub arena_reserved: usize,
    /// Arena budget, if one is configured.
    pub arena_budget: Option<usize>,
    /// The storage device is quarantined
    /// (`ssd::HealthTracker::is_degraded`): error/timeout rate crossed
    /// the threshold.  Treated as pressure — depth and prefetch shrink
    /// against a sick device rather than piling deeper queues onto it.
    pub device_degraded: bool,
}

impl GovernorSample {
    fn pressured(&self) -> bool {
        self.host_copy_bytes > 0 || self.degraded_tiles > 0 || self.device_degraded
    }

    fn stall_frac(&self) -> f64 {
        if self.step_secs <= 0.0 {
            return 0.0;
        }
        self.io_wait_secs / self.step_secs
    }

    fn busy_frac(&self) -> f64 {
        if self.step_secs <= 0.0 {
            return 0.0;
        }
        self.io_busy_secs / self.step_secs
    }
}

/// Running totals, for the step report and the bench JSON.
#[derive(Debug, Clone, Copy, Default)]
pub struct GovernorStats {
    pub shrinks: u64,
    pub grows: u64,
    pub steps: u64,
}

/// The feedback controller.  Owns a [`PipelineTuning`] and retunes it
/// from per-step [`GovernorSample`]s; see the module docs for the
/// control law.
pub struct PipelineGovernor {
    cfg: GovernorConfig,
    tuning: PipelineTuning,
    /// Knob values that caused pressure — growth stays strictly below
    /// them until [`GovernorConfig::reprobe_after`] clears them.
    ceiling: Option<PipelineTuning>,
    /// Fleet-imposed ceilings, overlaid at read time (never folded
    /// into `tuning` — see [`FleetCaps`]).
    caps: Option<FleetCaps>,
    pressure_free_steps: u64,
    steps_since_grow: u64,
    /// Round-robin cursor over the growable knobs.
    grow_cursor: usize,
    stats: GovernorStats,
}

impl PipelineGovernor {
    /// Start governing from `initial` (clamped into the config's
    /// bounds).
    pub fn new(cfg: GovernorConfig, initial: PipelineTuning) -> Self {
        let tuning = PipelineTuning {
            optim_tile_bytes: initial
                .optim_tile_bytes
                .clamp(cfg.min_tile_bytes, cfg.max_tile_bytes),
            tile_depth: initial.tile_depth.clamp(cfg.min_tile_depth, cfg.max_tile_depth),
            prefetch_depth: initial
                .prefetch_depth
                .clamp(cfg.min_prefetch_depth, cfg.max_prefetch_depth),
            sched_lead_us: initial.sched_lead_us.clamp(cfg.min_lead_us, cfg.max_lead_us),
            act_host_budget: initial
                .act_host_budget
                .clamp(cfg.min_act_budget, cfg.max_act_budget),
        };
        Self {
            cfg,
            tuning,
            ceiling: None,
            caps: None,
            pressure_free_steps: 0,
            steps_since_grow: 0,
            grow_cursor: 0,
            stats: GovernorStats::default(),
        }
    }

    /// The tuning the next step should run with (fleet caps applied).
    pub fn tuning(&self) -> PipelineTuning {
        self.capped()
    }

    /// Overlay (or lift, with `None`) fleet-imposed ceilings.
    pub fn set_caps(&mut self, caps: Option<FleetCaps>) {
        self.caps = caps;
    }

    fn capped(&self) -> PipelineTuning {
        match self.caps {
            Some(c) => c.clamp(self.tuning),
            None => self.tuning,
        }
    }

    pub fn stats(&self) -> GovernorStats {
        self.stats
    }

    /// Whether every knob sits at its configured minimum (the tuning
    /// can shrink no further).
    pub fn at_floor(&self) -> bool {
        self.tuning.optim_tile_bytes == self.cfg.min_tile_bytes
            && self.tuning.tile_depth == self.cfg.min_tile_depth
            && self.tuning.prefetch_depth == self.cfg.min_prefetch_depth
            && self.tuning.sched_lead_us == self.cfg.min_lead_us
            && self.tuning.act_host_budget == self.cfg.min_act_budget
    }

    /// Feed one step's observations; returns the tuning for the next
    /// step.
    pub fn observe(&mut self, s: &GovernorSample) -> PipelineTuning {
        self.stats.steps += 1;
        self.steps_since_grow = self.steps_since_grow.saturating_add(1);
        if s.pressured() {
            self.pressure_free_steps = 0;
            self.shrink(s);
            return self.capped();
        }
        self.pressure_free_steps += 1;
        if self.pressure_free_steps >= self.cfg.reprobe_after {
            // long pressure-free stretch: forget old ceilings so the
            // governor may re-probe larger windows (the budget
            // landscape may have changed — e.g. fewer spilled
            // activations late in a curriculum)
            self.ceiling = None;
        }
        if s.prefetch_late > 0
            && self.steps_since_grow >= self.cfg.grow_cooldown_steps
            && self.tuning.sched_lead_us < self.cfg.max_lead_us
        {
            // the replay schedule cut a deadline too fine: issue
            // earlier.  Targeted, not round-robin — a late fetch names
            // its own cure.
            self.tuning.sched_lead_us = self
                .tuning
                .sched_lead_us
                .max(1)
                .saturating_mul(2)
                .min(self.cfg.max_lead_us);
            self.stats.grows += 1;
            self.steps_since_grow = 0;
        } else if s.stall_frac() > self.cfg.grow_stall_frac
            && s.busy_frac() < self.cfg.busy_saturation_frac
            && self.steps_since_grow >= self.cfg.grow_cooldown_steps
        {
            self.grow(s);
        }
        self.capped()
    }

    /// Strictly-monotone shrink, targeted at the pressured component.
    fn shrink(&mut self, s: &GovernorSample) {
        let before = self.tuning;
        if s.host_copy_bytes > 0 && self.tuning.prefetch_depth > self.cfg.min_prefetch_depth
        {
            // delivery staging refused: fewer concurrent views first
            self.tuning.prefetch_depth -= 1;
        } else if s.host_copy_bytes > 0 && self.tuning.sched_lead_us > self.cfg.min_lead_us
        {
            // then fetch later: replayed units hold staging leases for
            // less wall time
            self.tuning.sched_lead_us =
                (self.tuning.sched_lead_us / 2).max(self.cfg.min_lead_us);
        } else if self.tuning.optim_tile_bytes > self.cfg.min_tile_bytes {
            self.tuning.optim_tile_bytes =
                (self.tuning.optim_tile_bytes / 2).max(self.cfg.min_tile_bytes);
        } else if self.tuning.tile_depth > self.cfg.min_tile_depth {
            self.tuning.tile_depth -= 1;
        } else if self.tuning.prefetch_depth > self.cfg.min_prefetch_depth {
            self.tuning.prefetch_depth -= 1;
        } else if self.tuning.sched_lead_us > self.cfg.min_lead_us {
            self.tuning.sched_lead_us =
                (self.tuning.sched_lead_us / 2).max(self.cfg.min_lead_us);
        } else if self.tuning.act_host_budget > self.cfg.min_act_budget {
            // last resort: trade activation spill I/O for pinned
            // headroom
            self.tuning.act_host_budget =
                (self.tuning.act_host_budget / 2).max(self.cfg.min_act_budget);
        }
        if self.tuning != before {
            self.stats.shrinks += 1;
            // remember what hurt: growth stays strictly below it
            self.ceiling = Some(match self.ceiling {
                None => before,
                Some(c) => PipelineTuning {
                    optim_tile_bytes: c.optim_tile_bytes.min(before.optim_tile_bytes),
                    tile_depth: c.tile_depth.min(before.tile_depth),
                    prefetch_depth: c.prefetch_depth.min(before.prefetch_depth),
                    sched_lead_us: c.sched_lead_us.min(before.sched_lead_us),
                    act_host_budget: c.act_host_budget.min(before.act_host_budget),
                },
            });
        }
        // all knobs at their minima and still pressured: the budget is
        // simply too small for the configuration — the drivers keep
        // degrading gracefully, which is the designed floor behavior
    }

    /// One grow action per call, round-robin over the growable knobs
    /// (lead-time grows only via its targeted late-arrival rule),
    /// ceilinged and budget-headroom-checked.
    fn grow(&mut self, s: &GovernorSample) {
        let ceiling = self.ceiling;
        let cfg = &self.cfg;
        let headroom = match (s.arena_budget, s.arena_reserved) {
            (Some(b), r) => b.saturating_sub(r),
            (None, _) => usize::MAX,
        };
        for _ in 0..4 {
            let knob = self.grow_cursor % 4;
            self.grow_cursor += 1;
            let mut next = self.tuning;
            let below_ceiling = |get: fn(&PipelineTuning) -> usize, v: usize| match ceiling
            {
                None => true,
                Some(c) => v < get(&c),
            };
            let allowed = match knob {
                0 => {
                    next.tile_depth += 1;
                    next.tile_depth <= cfg.max_tile_depth
                        && below_ceiling(|c| c.tile_depth, next.tile_depth)
                }
                1 => {
                    next.optim_tile_bytes =
                        (next.optim_tile_bytes * 2).min(cfg.max_tile_bytes);
                    next.optim_tile_bytes > self.tuning.optim_tile_bytes
                        && below_ceiling(|c| c.optim_tile_bytes, next.optim_tile_bytes)
                }
                2 => {
                    next.prefetch_depth += 1;
                    next.prefetch_depth <= cfg.max_prefetch_depth
                        && below_ceiling(|c| c.prefetch_depth, next.prefetch_depth)
                }
                _ => {
                    next.act_host_budget = next
                        .act_host_budget
                        .saturating_mul(2)
                        .min(cfg.max_act_budget);
                    next.act_host_budget > self.tuning.act_host_budget
                        && below_ceiling(|c| c.act_host_budget, next.act_host_budget)
                }
            };
            // projected extra pinned demand: the optimizer window delta
            // plus any activation-budget delta must fit the headroom
            let extra = next
                .optim_window_bytes()
                .saturating_sub(self.tuning.optim_window_bytes())
                .saturating_add(
                    next.act_host_budget.saturating_sub(self.tuning.act_host_budget),
                );
            if allowed && extra <= headroom {
                self.tuning = next;
                self.stats.grows += 1;
                self.steps_since_grow = 0;
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tuning(tile: usize, depth: usize, prefetch: usize) -> PipelineTuning {
        PipelineTuning {
            optim_tile_bytes: tile,
            tile_depth: depth,
            prefetch_depth: prefetch,
            // the defaults' minima, so the legacy three-knob tests keep
            // their exact expectations
            sched_lead_us: 200,
            act_host_budget: 0,
        }
    }

    fn pressured(host_copy: u64, degraded: u64) -> GovernorSample {
        GovernorSample {
            host_copy_bytes: host_copy,
            degraded_tiles: degraded,
            prefetch_late: 0,
            prefetch_hits: 0,
            io_wait_secs: 0.2,
            io_busy_secs: 0.4,
            step_secs: 1.0,
            arena_reserved: 0,
            arena_budget: None,
            device_degraded: false,
        }
    }

    fn calm() -> GovernorSample {
        GovernorSample {
            io_wait_secs: 0.0,
            io_busy_secs: 0.1,
            step_secs: 1.0,
            ..Default::default()
        }
    }

    fn stalled() -> GovernorSample {
        GovernorSample {
            io_wait_secs: 0.4,
            io_busy_secs: 0.5,
            step_secs: 1.0,
            ..Default::default()
        }
    }

    #[test]
    fn device_quarantine_counts_as_pressure_and_shrinks_the_pipeline() {
        let mut gov =
            PipelineGovernor::new(GovernorConfig::default(), tuning(4 << 20, 2, 6));
        gov.observe(&GovernorSample {
            device_degraded: true,
            step_secs: 1.0,
            ..Default::default()
        });
        let t = gov.tuning();
        assert!(
            t.optim_tile_bytes < 4 << 20,
            "a quarantined device must shrink the pipeline, got {t:?}"
        );
        // recovery: calm steps stop the shrinking
        let shrunk = gov.tuning();
        gov.observe(&calm());
        assert_eq!(gov.tuning(), shrunk);
    }

    #[test]
    fn persistent_pressure_converges_to_the_floor_in_bounded_steps() {
        let cfg = GovernorConfig::default();
        let mut gov =
            PipelineGovernor::new(cfg.clone(), tuning(cfg.max_tile_bytes, 8, 8));
        // worst case: one knob notch per step
        let bound = (usize::BITS as usize) // tile halvings
            + (cfg.max_tile_depth - cfg.min_tile_depth)
            + (cfg.max_prefetch_depth - cfg.min_prefetch_depth)
            + 4;
        let mut steps = 0;
        while !gov.at_floor() {
            gov.observe(&pressured(4096, 3));
            steps += 1;
            assert!(steps <= bound, "no convergence after {steps} steps");
        }
        // at the floor further pressure is absorbed without change
        let t = gov.tuning();
        gov.observe(&pressured(4096, 3));
        assert_eq!(gov.tuning(), t);
        assert_eq!(t.optim_tile_bytes, cfg.min_tile_bytes);
        assert_eq!(t.tile_depth, cfg.min_tile_depth);
        assert_eq!(t.prefetch_depth, cfg.min_prefetch_depth);
    }

    #[test]
    fn host_copy_pressure_shallows_prefetch_first() {
        let mut gov =
            PipelineGovernor::new(GovernorConfig::default(), tuning(4 << 20, 2, 6));
        gov.observe(&pressured(1024, 0));
        let t = gov.tuning();
        assert_eq!(t.prefetch_depth, 5, "prefetch must shrink first");
        assert_eq!(t.optim_tile_bytes, 4 << 20, "tile untouched on boundary pressure");
    }

    #[test]
    fn host_copy_pressure_pulls_lead_time_in_after_prefetch() {
        let cfg = GovernorConfig::default();
        let mut gov = PipelineGovernor::new(
            cfg.clone(),
            PipelineTuning { sched_lead_us: 8_000, ..tuning(4 << 20, 2, 1) },
        );
        // prefetch already at its floor: boundary pressure must halve
        // the schedule lead before touching the optimizer window
        gov.observe(&pressured(1024, 0));
        let t = gov.tuning();
        assert_eq!(t.sched_lead_us, 4_000, "lead-time must halve");
        assert_eq!(t.optim_tile_bytes, 4 << 20, "tile untouched");
        assert_eq!(t.prefetch_depth, 1);
    }

    #[test]
    fn degraded_tiles_shrink_the_tile_window_first() {
        let mut gov =
            PipelineGovernor::new(GovernorConfig::default(), tuning(4 << 20, 2, 6));
        gov.observe(&pressured(0, 2));
        let t = gov.tuning();
        assert_eq!(t.optim_tile_bytes, 2 << 20, "tile must halve");
        assert_eq!(t.prefetch_depth, 6, "prefetch untouched on optimizer pressure");
    }

    #[test]
    fn persistent_pressure_halves_the_activation_budget_last() {
        let cfg = GovernorConfig {
            min_act_budget: 1 << 20,
            max_act_budget: 16 << 20,
            ..Default::default()
        };
        let mut gov = PipelineGovernor::new(
            cfg.clone(),
            PipelineTuning {
                act_host_budget: 16 << 20,
                ..tuning(cfg.min_tile_bytes, 1, 1)
            },
        );
        // window knobs already at their floors: only the activation
        // budget is left to give, one halving per pressured step
        for expect in [8 << 20, 4 << 20, 2 << 20, 1 << 20] {
            gov.observe(&pressured(0, 1));
            assert_eq!(gov.tuning().act_host_budget, expect);
        }
        assert!(gov.at_floor());
        let t = gov.tuning();
        gov.observe(&pressured(0, 1));
        assert_eq!(gov.tuning(), t, "floor must absorb further pressure");
    }

    #[test]
    fn steady_state_without_stalls_never_changes_the_tuning() {
        let init = tuning(4 << 20, 2, 4);
        let mut gov = PipelineGovernor::new(GovernorConfig::default(), init);
        for _ in 0..200 {
            gov.observe(&calm());
        }
        assert_eq!(gov.tuning(), init, "calm steady state must be a fixed point");
        assert_eq!(gov.stats().shrinks + gov.stats().grows, 0);
    }

    #[test]
    fn stalls_grow_windows_under_cooldown_and_bounds() {
        let cfg = GovernorConfig::default();
        let init = tuning(cfg.min_tile_bytes, 1, 1);
        let mut gov = PipelineGovernor::new(cfg.clone(), init);
        for _ in 0..500 {
            gov.observe(&stalled());
        }
        let t = gov.tuning();
        // everything grew to its max, and never beyond (the activation
        // budget starts — and stays — at zero: doubling nothing)
        assert_eq!(t.optim_tile_bytes, cfg.max_tile_bytes);
        assert_eq!(t.tile_depth, cfg.max_tile_depth);
        assert_eq!(t.prefetch_depth, cfg.max_prefetch_depth);
        assert_eq!(t.act_host_budget, 0);
        // cooldown bounds the grow rate
        assert!(gov.stats().grows <= 500 / cfg.grow_cooldown_steps + 3);
    }

    #[test]
    fn late_prefetches_double_the_schedule_lead_under_cooldown() {
        let cfg = GovernorConfig::default();
        let mut gov = PipelineGovernor::new(
            cfg.clone(),
            PipelineTuning { sched_lead_us: 1_000, ..tuning(4 << 20, 2, 4) },
        );
        let late = GovernorSample { prefetch_late: 3, prefetch_hits: 9, ..calm() };
        for _ in 0..200 {
            gov.observe(&late);
        }
        let t = gov.tuning();
        assert_eq!(t.sched_lead_us, cfg.max_lead_us, "lead must ride up to its cap");
        // targeted growth: the window knobs stay put (no stall signal)
        assert_eq!(t.optim_tile_bytes, 4 << 20);
        assert_eq!(t.tile_depth, 2);
        assert_eq!(t.prefetch_depth, 4);
        // cooldown applies to lead growth like any other grow action
        assert!(gov.stats().grows <= 200 / cfg.grow_cooldown_steps + 1);
    }

    #[test]
    fn activation_budget_grows_in_rotation_and_respects_headroom() {
        let cfg = GovernorConfig {
            min_act_budget: 1 << 20,
            max_act_budget: 8 << 20,
            ..Default::default()
        };
        let mut gov = PipelineGovernor::new(
            cfg.clone(),
            PipelineTuning { act_host_budget: 1 << 20, ..tuning(4 << 20, 2, 4) },
        );
        for _ in 0..300 {
            gov.observe(&stalled());
        }
        assert_eq!(
            gov.tuning().act_host_budget,
            8 << 20,
            "unconstrained stalls must grow the activation budget to its cap"
        );

        // zero headroom: the activation budget must not grow — its
        // doubling is pinned demand like any window knob's
        let mut gov = PipelineGovernor::new(
            cfg.clone(),
            PipelineTuning { act_host_budget: 1 << 20, ..tuning(4 << 20, 2, 4) },
        );
        let mut s = stalled();
        s.arena_budget = Some(100 << 20);
        s.arena_reserved = 100 << 20;
        for _ in 0..50 {
            gov.observe(&s);
        }
        assert_eq!(gov.tuning().act_host_budget, 1 << 20, "act grew with zero headroom");
    }

    #[test]
    fn growth_respects_budget_headroom() {
        let cfg = GovernorConfig::default();
        let init = tuning(1 << 20, 2, 1);
        let mut gov = PipelineGovernor::new(cfg, init);
        // zero headroom: stalls alone must not grow the optimizer
        // window past what the budget can hold
        let mut s = stalled();
        s.arena_budget = Some(100 << 20);
        s.arena_reserved = 100 << 20;
        for _ in 0..50 {
            gov.observe(&s);
        }
        let t = gov.tuning();
        assert_eq!(t.optim_tile_bytes, 1 << 20, "tile grew with zero headroom");
        assert_eq!(t.tile_depth, 2, "depth grew with zero headroom");
        // prefetch growth is not optimizer-window-bounded, so it may
        // deepen; the boundary pressure signal governs it instead
        assert!(t.prefetch_depth >= 1);
    }

    #[test]
    fn pressure_ceiling_prevents_shrink_grow_ping_pong() {
        let cfg = GovernorConfig { reprobe_after: 1000, ..Default::default() };
        let mut gov = PipelineGovernor::new(cfg, tuning(4 << 20, 2, 2));
        // pressure at 4 MiB tiles: shrink to 2 MiB, remember 4 MiB hurt
        gov.observe(&pressured(0, 1));
        assert_eq!(gov.tuning().optim_tile_bytes, 2 << 20);
        // stalls now: growth may re-approach but never reach 4 MiB
        for _ in 0..100 {
            gov.observe(&stalled());
        }
        assert!(
            gov.tuning().optim_tile_bytes < 4 << 20,
            "governor re-entered the pressured regime"
        );
    }

    #[test]
    fn reprobe_clears_ceilings_after_a_long_calm_stretch() {
        let cfg = GovernorConfig { reprobe_after: 8, ..Default::default() };
        let mut gov = PipelineGovernor::new(cfg, tuning(4 << 20, 2, 2));
        gov.observe(&pressured(0, 1));
        let shrunk = gov.tuning().optim_tile_bytes;
        assert!(shrunk < 4 << 20);
        for _ in 0..8 {
            gov.observe(&calm());
        }
        // ceilings cleared: stalls may now grow past the old ceiling
        for _ in 0..100 {
            gov.observe(&stalled());
        }
        assert!(gov.tuning().optim_tile_bytes >= 4 << 20, "ceiling never cleared");
    }

    #[test]
    fn fleet_caps_overlay_without_corrupting_internal_state() {
        let mut gov =
            PipelineGovernor::new(GovernorConfig::default(), tuning(4 << 20, 6, 6));
        let full = gov.tuning();
        gov.set_caps(Some(FleetCaps {
            max_tile_depth: 2,
            max_prefetch_depth: 1,
            max_act_budget: 0,
        }));
        let t = gov.observe(&calm());
        assert_eq!(t.tile_depth, 2);
        assert_eq!(t.prefetch_depth, 1);
        // lifting the caps restores the governor's own tuning exactly —
        // the overlay never folded into internal state
        gov.set_caps(None);
        assert_eq!(gov.tuning(), full);
        // depth caps floor at 1: a fleet can throttle a job to serial
        // progress but never wedge it
        gov.set_caps(Some(FleetCaps {
            max_tile_depth: 0,
            max_prefetch_depth: 0,
            max_act_budget: usize::MAX,
        }));
        assert_eq!(gov.tuning().tile_depth, 1);
        assert_eq!(gov.tuning().prefetch_depth, 1);
    }

    #[test]
    fn initial_tuning_is_clamped_into_bounds() {
        let cfg = GovernorConfig::default();
        let gov = PipelineGovernor::new(cfg.clone(), tuning(1, 0, 100));
        let t = gov.tuning();
        assert_eq!(t.optim_tile_bytes, cfg.min_tile_bytes);
        assert_eq!(t.tile_depth, cfg.min_tile_depth);
        assert_eq!(t.prefetch_depth, cfg.max_prefetch_depth);
        // the new knobs clamp too
        let t2 = PipelineGovernor::new(
            cfg.clone(),
            PipelineTuning { sched_lead_us: 1, ..tuning(4 << 20, 2, 2) },
        )
        .tuning();
        assert_eq!(t2.sched_lead_us, cfg.min_lead_us);
    }

    /// The integration shape of the convergence claim: a real tiled
    /// optimizer under a real budget-capped arena, with a concurrent
    /// delivery-staging consumer.  Static config degrades (tiles and
    /// delivery both refused); the governed loop shrinks windows until
    /// both `degraded_tiles` and `host_copy_bytes` return to 0 and
    /// stay there.
    #[test]
    fn governed_tiled_optimizer_converges_under_a_tight_budget() {
        use crate::metrics::HostCopyMeter;
        use crate::optimizer::{step_groups_tiled, AdamParams, OptimState, StateDtype};
        use crate::pinned::{
            AlignedAllocator, ArenaConfig, Cat, MemoryTracker, Mode, PinnedArena,
        };
        use crate::runtime::F32Staging;
        use crate::ssd::{AsyncEngine, DirectEngine, NvmeEngine};
        use crate::util::stage::StageExecutor;
        use std::sync::Arc;

        let dir = std::env::temp_dir()
            .join(format!("ma-gov-conv-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let eng: Arc<dyn NvmeEngine> =
            Arc::new(DirectEngine::new(&dir, 1, 1 << 26, 1).unwrap());
        let n = 200_000usize; // 800 KiB per f32 stream
        let mut rng = crate::util::rng::Xoshiro256::new(7);
        let p0: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let st = OptimState::init(eng.as_ref(), "g0", &p0, StateDtype::F32).unwrap();
        let aio = AsyncEngine::new(Arc::clone(&eng), 2);
        let stage = StageExecutor::new(1);
        let hp = AdamParams::default();

        let budget = 1 << 20; // 1 MiB pinned for everything
        let arena = PinnedArena::new(
            Arc::new(AlignedAllocator::new(Mode::Real, Arc::new(MemoryTracker::new()))),
            ArenaConfig { budget_bytes: Some(budget), ..Default::default() },
        );
        let meter = HostCopyMeter::new();
        // delivery view: 96 KiB per prefetch slot, like the swapper's
        // decoded weight views
        let view_elems = 24 << 10;

        let cfg = GovernorConfig {
            min_tile_bytes: 8 << 10,
            max_tile_bytes: 1 << 20,
            ..Default::default()
        };
        // static config: 512 KiB tiles x depth 2 x 7 leases cannot fit
        // 1 MiB next to the delivery views
        let mut gov = PipelineGovernor::new(cfg, tuning(512 << 10, 2, 4));
        let mut clean_streak = 0;
        let mut saw_pressure = false;
        for t in 1..=40u64 {
            let tun = gov.tuning();
            // hold `prefetch_depth` delivery views across the step,
            // like in-flight decoded weights
            let before_copies = meter.bytes();
            let views: Vec<F32Staging> = (0..tun.prefetch_depth)
                .map(|_| F32Staging::take(&arena, Cat::SwapBuf, view_elems, &meter))
                .collect();
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let stats = step_groups_tiled(
                &aio,
                &stage,
                &arena,
                std::slice::from_ref(&st),
                &[g.as_slice()],
                &["g0/fp16".to_string()],
                t,
                1.0,
                &hp,
                1,
                tun.optim_tile_bytes,
                tun.tile_depth,
            )
            .unwrap();
            drop(views);
            let host_copy = meter.bytes() - before_copies;
            if host_copy > 0 || stats.degraded_tiles > 0 {
                saw_pressure = true;
                clean_streak = 0;
            } else {
                clean_streak += 1;
            }
            let arena_stats = arena.stats();
            gov.observe(&GovernorSample {
                host_copy_bytes: host_copy,
                degraded_tiles: stats.degraded_tiles,
                prefetch_late: 0,
                prefetch_hits: 0,
                io_wait_secs: stats.wait_secs,
                io_busy_secs: 0.0,
                step_secs: 1.0,
                arena_reserved: arena_stats.reserved_bytes,
                arena_budget: Some(budget),
            });
            if clean_streak >= 5 {
                break;
            }
        }
        assert!(saw_pressure, "the static starting point never pressured — test is vacuous");
        assert!(
            clean_streak >= 5,
            "governor failed to converge: tuning {:?} after {} shrinks",
            gov.tuning(),
            gov.stats().shrinks
        );
        assert!(gov.stats().shrinks > 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
