//! The trainer: real SSD-offloaded fine-tuning on the PJRT runtime.
//!
//! This is the end-to-end validation path (DESIGN.md §6): every
//! parameter lives on the simulated SSD (fp16 compute copy + fp32/bf16
//! optimizer states), blocks stream through the buffer pool per layer,
//! activations checkpoint to pinned host memory, gradients ride an
//! fp16 transport into the fp32 flat buffer, the (fused or baseline)
//! overflow check gates a dynamic loss scaler, and the CPU Adam swaps
//! state subgroups through the NVMe engine — ZeRO-Infinity's full data
//! flow, with MemAscend's optimizations toggleable per component.
//!
//! The pipeline's window knobs (optimizer tile size, tile-pipeline
//! depth, swapper prefetch depth) are owned by a [`PipelineTuning`]:
//! static from `TrainSpec` by default, retuned once per step by the
//! pressure-adaptive [`PipelineGovernor`] ([`governor`]) when
//! `TrainSpec::governor` is set — shrinking windows when the pinned
//! arena degrades the zero-copy or tiled paths
//! (`host_copy_bytes`/`degraded_tiles` > 0), deepening them when the
//! step stalls on I/O with idle queues.  With
//! `TrainSpec::optim_coalesce_bytes` set, the per-tensor optimizer
//! groups coalesce into super-group streams
//! ([`crate::optimizer::CoalescedOptim`]) so each tile drives one long
//! ranged submission instead of a per-tensor burst.

pub mod data;
pub mod governor;
pub mod trainer;
pub mod weights;

pub use data::Corpus;
pub use governor::{
    FleetCaps, GovernorConfig, GovernorSample, GovernorStats, PipelineGovernor, PipelineTuning,
};
pub use trainer::{TrainOpts, Trainer};
pub use weights::init_weights;
