//! The trainer: real SSD-offloaded fine-tuning on the PJRT runtime.
//!
//! This is the end-to-end validation path (DESIGN.md §6): every
//! parameter lives on the simulated SSD (fp16 compute copy + fp32/bf16
//! optimizer states), blocks stream through the buffer pool per layer,
//! activations checkpoint to pinned host memory, gradients ride an
//! fp16 transport into the fp32 flat buffer, the (fused or baseline)
//! overflow check gates a dynamic loss scaler, and the CPU Adam swaps
//! state subgroups through the NVMe engine — ZeRO-Infinity's full data
//! flow, with MemAscend's optimizations toggleable per component.

pub mod data;
pub mod trainer;
pub mod weights;

pub use data::Corpus;
pub use trainer::{TrainOpts, Trainer};
pub use weights::init_weights;
