//! The training loop: ZeRO-Infinity data flow over the PJRT runtime.
//!
//! Per step (Fig. 1, adapted to the staged artifacts):
//!
//! 1. forward — the swapper streams each block's fp16 weights from the
//!    NVMe engine through the parameter pool ahead of compute; each
//!    block's input hidden state checkpoints to pinned host memory
//!    (offloaded gradient checkpointing);
//! 2. head — fused linear+CE stage returns loss and *scaled* gradients;
//! 3. backward — blocks run in reverse; `block_bwd` recomputes the
//!    forward from the checkpoint internally (that *is* gradient
//!    checkpointing) and yields weight gradients, which ride an fp16
//!    transport into the fp32 flat buffer;
//! 4. overflow check (fused or baseline) gates the dynamic loss scaler;
//! 5. CPU AdamW swaps optimizer-state subgroups through the engine and
//!    writes fresh fp16 compute weights back to the SSD — when
//!    `TrainSpec::io_workers > 0`, via the staged-tile pipeline
//!    (`TrainSpec::optim_tile_bytes` fixed-byte tiles, conversions on
//!    the compute-side stage pool, peak pinned staging independent of
//!    group size) or the whole-group double-buffer when the tile knob
//!    is 0; sequential otherwise.  With
//!    `TrainSpec::optim_coalesce_bytes` set, the per-tensor groups
//!    coalesce into super-group streams first
//!    ([`crate::optimizer::CoalescedOptim`]) so each tile is one long
//!    ranged submission instead of a per-tensor burst.  All paths are
//!    bit-identical.
//!
//! The pipeline's knobs — optimizer tile size, tile depth, the
//! swapper's prefetch depth, the replay schedule's lead-time, and the
//! activation store's host budget — live in a [`PipelineTuning`]: the
//! spec's static values by default, retuned after every step by the
//! pressure-adaptive [`PipelineGovernor`] when `TrainSpec::governor`
//! is on (shrink on `host_copy_bytes`/`degraded_tiles` pressure, grow
//! on stalls with idle queues and budget headroom, lead-time up on
//! `prefetch_late` — see [`super::governor`]).  Since every retune
//! only resizes disjoint-range I/O windows or moves activation bytes
//! between host and SSD tiers, governed and static runs are
//! bit-identical in results; only speed and pinned footprint differ.
//!
//! With `TrainSpec::fetch_coalesce` (on top of coalesced optimizer
//! streams) the swapper gathers each super-group of fp16 weights with
//! one ranged read over the packed `optim/sg{i}/fp16` streams instead
//! of 7 per-tensor reads, and with `TrainSpec::prefetch_profile` it
//! records the first pass's fetch timings per plan shape and replays
//! later passes on a rate-matched just-in-time schedule
//! ([`crate::offload::prefetch`]); the profile persists with each
//! checkpoint epoch and is digest-revalidated on resume, degrading to
//! the depth window (and re-recording) on mismatch.
//!
//! Weight fetches ride the swapper's windowed pipeline and arrive as
//! **lease-backed views** ([`TensorBuf`]): the f16→f32 decode lands in
//! pinned arena memory, the argument list borrows those bytes
//! ([`ValueRef`]), and `Runtime::run` uploads them verbatim — zero
//! fp32 host-to-host copies between NVMe fetch and PJRT upload, for
//! streamed weights, resident norms (borrowed in place, no
//! `.to_vec()`), and recomputation checkpoints alike.  Owned vectors
//! appear only where PJRT *produces* them (stage results) or where the
//! arena budget degrades a fetch — those staged bytes are counted in
//! `StepMetrics::host_copy_bytes` (0 in steady state) — and recycle
//! through the shared [`F32Scratch`] pool.  The step report carries
//! `io_wait_secs` — the foreground I/O stall, including activation
//! spill fetches — next to the engine-busy `io_secs` (an exact
//! union-of-busy-intervals measure) so the overlap the pipeline wins
//! is measurable (`StepMetrics::io_overlap_secs`).
//!
//! Data-parallel ranks are simulated round-robin on the single PJRT
//! device: each rank's microbatch accumulates into the shared flat
//! buffer and the unscale divide folds in the rank count — numerically
//! identical to reduce-scatter + per-rank update (collective/ tests
//! prove the partitioned math separately).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::ckpt::{self, CkptState, Journal};
use crate::config::{ModelSpec, TrainSpec};
use crate::jobs::JobCtx;
use crate::metrics::{RunReport, StepMetrics};
use crate::util::events::{Event, EventKind};
use crate::offload::SpillingActivationStore;
use crate::offload::{
    F32Scratch, FetchGroups, FetchOpts, GradFlatBuffer, LossScaler, OffloadEngine,
    ProfileStore, Swapper,
};
use crate::optimizer::{AdamParams, CoalescedOptim, StateDtype};
use crate::runtime::{Runtime, TensorBuf, ValueRef};
use crate::tensors::TensorDesc;
use crate::train::data::Corpus;
use crate::train::governor::{GovernorConfig, GovernorSample, PipelineGovernor, PipelineTuning};
use crate::train::weights::{fp16_key, init_weights, resume_weights, ModelState};

#[derive(Debug, Clone)]
pub struct TrainOpts {
    pub steps: usize,
    pub seed: u64,
    pub log_every: usize,
    /// Optional CSV path for the loss curve.
    pub loss_csv: Option<String>,
}

impl Default for TrainOpts {
    fn default() -> Self {
        Self { steps: 20, seed: 42, log_every: 10, loss_csv: None }
    }
}

pub struct Trainer {
    rt: Arc<Runtime>,
    pub engine: OffloadEngine,
    spec: &'static ModelSpec,
    train: TrainSpec,
    state: ModelState,
    flat: GradFlatBuffer,
    scaler: LossScaler,
    corpus: Corpus,
    hp: AdamParams,
    applied_steps: u64,
    /// Steps completed on this storage, across resumes — `run` numbers
    /// its steps and the checkpoint cadence from it.
    steps_done: u64,
    /// Weight-init / data seed this storage was created with; journaled
    /// so resume can refuse a mismatched restart.
    seed: u64,
    /// Dual-slot epoch journal over the engine
    /// ([`crate::ckpt::Journal`]).  Always constructed; commits only
    /// happen at `TrainSpec::ckpt_interval_steps` cadence.
    journal: Journal,
    /// Newest epoch committed on this storage (0 = none).  Post-commit
    /// optimizer write-backs land on the *other* physical extent of
    /// each shadow-paged key ([`crate::ckpt::ShadowEngine`]), so this
    /// epoch's bytes stay recoverable no matter where the next window
    /// crashes.
    last_epoch: u64,
    /// Offloadable tensors in forward order (the swapper plan).
    fwd_plan: Vec<TensorDesc>,
    /// Block weight result order, resolved from the manifest once.
    block_names: Vec<String>,
    /// Recycled f32 buffers shared with the swapper pipeline.
    scratch: Arc<F32Scratch>,
    /// The pipeline window knobs this step runs with: the spec's
    /// static values, or the governor's latest retune.
    tuning: PipelineTuning,
    /// Pressure-adaptive retuning loop (`TrainSpec::governor`); only
    /// engages on the tiled optimizer path.
    governor: Option<PipelineGovernor>,
    /// Super-group coalesced optimizer streams
    /// (`TrainSpec::optim_coalesce_bytes`); `None` = per-tensor
    /// groups, today's layout.
    coalesced: Option<CoalescedOptim>,
    /// Coalesced *fetch* groups over the packed fp16 read streams
    /// (`TrainSpec::fetch_coalesce`): the swapper gathers each
    /// super-group with one ranged read instead of 7 per-tensor reads.
    fetch_groups: Option<Arc<FetchGroups>>,
    /// Recorded step-profile store (`TrainSpec::prefetch_profile`):
    /// the swapper records the first pass per plan shape and replays
    /// later passes on a rate-matched just-in-time schedule.  Shared
    /// with every swapper; persisted at checkpoint commits.
    profile: Option<Arc<ProfileStore>>,
    /// Tenancy identity: job id (tags every scheduler submission),
    /// structured event sink, and the optional fleet governor whose
    /// caps overlay this trainer's tuning.  `JobCtx::default()` — the
    /// host identity — for solo runs.
    ctx: JobCtx,
    /// Round-robin cursor into [`Self::shadow_key_set`] for the
    /// idle-time scrub walk (`TrainSpec::scrub`): each inter-step gap
    /// re-reads and re-verifies a couple of streams, so silent rot
    /// surfaces within one pass over the key set instead of at the
    /// next (possibly much later) fetch.
    scrub_cursor: usize,
}

/// Governor bounds that admit the starting tuning, so enabling the
/// governor never silently rewrites a configured knob — adaptation
/// starts exactly where the static configuration would have run.  The
/// activation-budget bounds derive from the spec (floor = an eighth of
/// the configured budget); an unbudgeted store pins `min == max ==
/// usize::MAX`, leaving that knob dormant.
fn governor_config(train: &TrainSpec, start: PipelineTuning) -> GovernorConfig {
    let d = GovernorConfig::default();
    let (min_act, max_act) = if train.act_host_budget == usize::MAX {
        (usize::MAX, usize::MAX)
    } else {
        (train.act_host_budget / 8, train.act_host_budget)
    };
    GovernorConfig {
        min_tile_bytes: d.min_tile_bytes.min(start.optim_tile_bytes),
        max_tile_bytes: d.max_tile_bytes.max(start.optim_tile_bytes),
        max_tile_depth: d.max_tile_depth.max(start.tile_depth),
        max_prefetch_depth: d.max_prefetch_depth.max(start.prefetch_depth),
        min_lead_us: d.min_lead_us.min(start.sched_lead_us),
        max_lead_us: d.max_lead_us.max(start.sched_lead_us),
        min_act_budget: min_act.min(start.act_host_budget),
        max_act_budget: max_act.max(start.act_host_budget),
        ..d
    }
}

impl Trainer {
    /// Load the PJRT runtime and check it matches the train shape —
    /// shared by every constructor (solo and tenant).
    pub fn load_runtime(artifacts_dir: &Path, train: &TrainSpec) -> anyhow::Result<Arc<Runtime>> {
        let rt = Arc::new(Runtime::load(artifacts_dir)?);
        anyhow::ensure!(
            rt.manifest().config.seq == train.seq
                && rt.manifest().config.batch == train.batch,
            "artifacts were exported for batch={} seq={}; re-export or adjust",
            rt.manifest().config.batch,
            rt.manifest().config.seq
        );
        Ok(rt)
    }

    pub fn new(
        artifacts_dir: &Path,
        storage_dir: &Path,
        train: TrainSpec,
        opts: &TrainOpts,
    ) -> anyhow::Result<Self> {
        let rt = Self::load_runtime(artifacts_dir, &train)?;
        let spec = rt.manifest().model_spec()?;
        let engine = OffloadEngine::new(spec, &train, storage_dir)?;
        Self::with_engine(rt, engine, train, opts, JobCtx::default())
    }

    /// [`Self::new`] over a pre-built engine (view) — the multi-tenant
    /// entry point: pass an [`OffloadEngine::job_view`] and the job's
    /// [`JobCtx`] to run this trainer as one tenant of a shared stack.
    /// `Trainer::new` is exactly `with_engine(root engine, host ctx)`.
    pub fn with_engine(
        rt: Arc<Runtime>,
        engine: OffloadEngine,
        train: TrainSpec,
        opts: &TrainOpts,
        ctx: JobCtx,
    ) -> anyhow::Result<Self> {
        let spec = rt.manifest().model_spec()?;
        let state_dtype = match train.optim_dtype {
            crate::dtype::DType::BF16 => StateDtype::BF16,
            _ => StateDtype::F32,
        };
        // a fresh initialization is about to overwrite whatever a
        // previous run left on this storage — retire any stale journal
        // records *first*.  A stale record over freshly-initialized
        // extents could validate by key lengths alone and resume into
        // silently divergent state; zeroing both slots turns a crash
        // mid-init into a structured "no checkpoint journal" error.
        let journal = Journal::new(engine.nvme.clone());
        journal.invalidate()?;
        let state = init_weights(spec, engine.nvme.as_ref(), state_dtype, opts.seed)?;
        let flat = GradFlatBuffer::new(&state.inv, &engine.arena)?;
        let scaler = if train.precision.needs_overflow_check() {
            LossScaler::new(train.init_loss_scale, train.scale_growth_interval)
        } else {
            LossScaler::disabled()
        };
        let corpus = Corpus::new(spec.vocab, opts.seed ^ 0xC0FFEE);
        let hp = AdamParams {
            lr: train.lr,
            beta1: train.beta1,
            beta2: train.beta2,
            eps: train.eps,
            weight_decay: train.weight_decay,
        };
        let fwd_plan: Vec<TensorDesc> =
            state.inv.iter().filter(|t| t.offloadable()).cloned().collect();
        let block_names = rt.manifest().block_weight_names.clone();
        let scratch = Arc::new(F32Scratch::with_meter(
            engine.arena.clone(),
            engine.copy_meter.clone(),
        ));
        // the governor and the coalescer both ride the staged-tile
        // optimizer; neither engages on the whole-group or sequential
        // paths (the paper-parity configurations stay byte-identical)
        let tiled = train.io_workers > 0 && train.optim_tile_bytes > 0;
        let tuning = PipelineTuning {
            optim_tile_bytes: train.optim_tile_bytes,
            tile_depth: train.optim_tile_depth.max(1),
            prefetch_depth: train.prefetch_depth.max(1),
            sched_lead_us: train.prefetch_lead_us,
            act_host_budget: train.act_host_budget,
        };
        let governor = (train.governor && tiled)
            .then(|| PipelineGovernor::new(governor_config(&train, tuning), tuning));
        debug_assert!(
            governor.as_ref().map_or(tuning, |g| g.tuning()) == tuning,
            "governor bounds must admit the spec's starting point"
        );
        let mut coalesced = (tiled && train.optim_coalesce_bytes > 0)
            .then(|| {
                CoalescedOptim::build(
                    engine.nvme.as_ref(),
                    &state.offloaded,
                    train.optim_coalesce_bytes,
                )
            })
            .transpose()?;
        let fetch_groups = match (&mut coalesced, train.fetch_coalesce) {
            (Some(co), true) => {
                // mirror the member fp16 keys into packed read streams
                // and hand the swapper the layout to gather over
                let keys: Vec<String> =
                    state.offloaded.iter().map(|st| fp16_key(&st.group)).collect();
                co.enable_fp16_streams(engine.nvme.as_ref(), &keys)?;
                Some(Arc::new(FetchGroups::from_layout(&co.layout)))
            }
            _ => None,
        };
        let profile = train.prefetch_profile.then(|| Arc::new(ProfileStore::new()));
        let trainer = Self {
            rt,
            engine,
            spec,
            train,
            state,
            flat,
            scaler,
            corpus,
            hp,
            applied_steps: 0,
            steps_done: 0,
            seed: opts.seed,
            journal,
            last_epoch: 0,
            fwd_plan,
            block_names,
            scratch,
            tuning,
            governor,
            coalesced,
            fetch_groups,
            profile,
            ctx,
            scrub_cursor: 0,
        };
        // shadow-page every checkpointed stream: until the first commit
        // flips, registered keys resolve to extent 0 (the bytes
        // init_weights just wrote), so this is a pure pass-through
        trainer.engine.shadow.register(trainer.shadow_key_set());
        trainer.wire_robustness_sinks();
        Ok(trainer)
    }

    /// Route the engine's health and integrity diagnostics to this
    /// trainer's event sink: quarantine transitions from the shared
    /// executor's [`crate::ssd::HealthTracker`] and checksum-mismatch
    /// events from the [`crate::ssd::IntegrityEngine`] layer (when
    /// `TrainSpec::verify_reads` built one).
    fn wire_robustness_sinks(&self) {
        self.engine.ioq.health().set_sink(self.ctx.events.clone());
        if let Some(integrity) = &self.engine.integrity {
            integrity.set_sink(self.ctx.events.clone());
        }
    }

    /// Reopen a checkpointed run and continue bit-identically from its
    /// newest committed epoch.
    ///
    /// The inverse of [`Self::new`] over storage that already holds the
    /// training state: replays the journal instead of re-initializing
    /// weights (no RNG consumed, no SSD writes, no DRAM re-staging of
    /// optimizer state — the tensors stay on the SSD and only the small
    /// resident norms read back), and restores the loss scaler,
    /// data-loader RNG cursor, and step counters.
    ///
    /// Recovery walks the journal newest-first: each candidate epoch is
    /// validated against the storage inventory (every key length at the
    /// journaled extent, every resident-blob checksum, the
    /// coalesce-layout digest), its extent map is installed on the
    /// shadow layer, and the first epoch that fully verifies wins.  A
    /// damaged newest epoch (torn slot, bit-rot, crash mid-commit) is
    /// reported and skipped — shadow paging guarantees the previous
    /// epoch's extents were never overwritten, so walking back always
    /// lands on intact bytes.  Hard errors remain for operator
    /// mistakes: no journal at all, or a resume configuration
    /// (model/seed/dtype/coalesce mode) that diverges from the
    /// journaled one.
    pub fn resume(
        artifacts_dir: &Path,
        storage_dir: &Path,
        train: TrainSpec,
        opts: &TrainOpts,
    ) -> anyhow::Result<Self> {
        let rt = Self::load_runtime(artifacts_dir, &train)?;
        let spec = rt.manifest().model_spec()?;
        let engine = OffloadEngine::new(spec, &train, storage_dir)?;
        Self::resume_with_engine(rt, engine, train, opts, JobCtx::default())
    }

    /// [`Self::resume`] over a pre-built engine (view): a tenant
    /// recovers from *its own* shadow-paged epochs on the shared
    /// device (keys are job-prefixed, so journals never collide).
    /// Skipped-epoch and profile-divergence diagnostics go to the
    /// ctx's event sink, attributed to its job.
    pub fn resume_with_engine(
        rt: Arc<Runtime>,
        engine: OffloadEngine,
        train: TrainSpec,
        opts: &TrainOpts,
        ctx: JobCtx,
    ) -> anyhow::Result<Self> {
        let spec = rt.manifest().model_spec()?;
        let journal = Journal::new(engine.nvme.clone());
        let candidates = journal.load_all();
        anyhow::ensure!(
            !candidates.is_empty(),
            "no checkpoint journal on this storage — start the run with \
             --ckpt-interval > 0 (TrainSpec::ckpt_interval_steps) to make \
             it resumable"
        );
        let state_dtype = match train.optim_dtype {
            crate::dtype::DType::BF16 => StateDtype::BF16,
            _ => StateDtype::F32,
        };
        let dtype_label = match state_dtype {
            StateDtype::BF16 => "bf16",
            StateDtype::F32 => "f32",
        };
        let tiled = train.io_workers > 0 && train.optim_tile_bytes > 0;
        let coalesce_cfg = tiled && train.optim_coalesce_bytes > 0;

        // walk the journaled epochs newest-first and take the first one
        // that fully verifies; shadow paging kept every older epoch's
        // extents intact, so walking back always lands on real bytes
        let mut chosen = None;
        let mut last_err: Option<anyhow::Error> = None;
        for ck in candidates {
            // configuration mismatches are operator errors, not storage
            // damage — never walk past them to an older epoch
            anyhow::ensure!(
                ck.model == spec.name,
                "checkpoint was taken for model '{}', resume asked for '{}'",
                ck.model,
                spec.name
            );
            anyhow::ensure!(
                ck.seed == opts.seed,
                "checkpoint was seeded with {}, resume requested {} (pass the \
                 original seed)",
                ck.seed,
                opts.seed
            );
            anyhow::ensure!(
                ck.dtype == dtype_label,
                "checkpoint optimizer state is {}, resume requested {dtype_label}",
                ck.dtype
            );
            anyhow::ensure!(
                coalesce_cfg == ck.layout_digest.is_some(),
                "checkpoint {} coalesced optimizer streams but this resume {} \
                 (keep optim_coalesce_bytes consistent across restarts)",
                if ck.layout_digest.is_some() { "used" } else { "did not use" },
                if coalesce_cfg { "does" } else { "does not" },
            );
            let attempt = (|| -> anyhow::Result<ModelState> {
                ck.validate_keys(engine.nvme.as_ref())?;
                if let Some(want) = ck.layout_digest {
                    let got = ckpt::stored_digest(
                        engine.nvme.as_ref(),
                        crate::optimizer::coalesce::LAYOUT_KEY,
                    )?;
                    anyhow::ensure!(
                        got == Some(want),
                        "persisted coalesce-layout blob diverged from the \
                         journaled digest — storage was re-laid since the \
                         checkpoint"
                    );
                }
                // route every logical key to the physical extent this
                // epoch committed, then rebuild from metadata plus the
                // (checksummed) resident blobs — init_weights is never
                // called, so nothing on the SSD is rewritten
                engine.shadow.install(ck.extent_map());
                resume_weights(spec, engine.nvme.as_ref(), state_dtype)
            })();
            match attempt {
                Ok(state) => {
                    chosen = Some((ck, state));
                    break;
                }
                Err(e) => {
                    ctx.events.emit(Event {
                        job: ctx.job,
                        kind: EventKind::ResumeEpochSkipped { epoch: ck.epoch },
                        detail: format!("{e:#}"),
                    });
                    last_err = Some(e);
                }
            }
        }
        let (ck, state) = match chosen {
            Some(found) => found,
            None => {
                return Err(last_err
                    .expect("candidates were non-empty")
                    .context("no journaled epoch is recoverable"))
            }
        };
        let flat = GradFlatBuffer::new(&state.inv, &engine.arena)?;
        let mut scaler = if train.precision.needs_overflow_check() {
            LossScaler::new(train.init_loss_scale, train.scale_growth_interval)
        } else {
            LossScaler::disabled()
        };
        scaler.restore((ck.scale, ck.good_steps, ck.overflows, ck.growths));
        let mut corpus = Corpus::new(spec.vocab, opts.seed ^ 0xC0FFEE);
        corpus.set_rng_state(ck.corpus_rng);
        let hp = AdamParams {
            lr: train.lr,
            beta1: train.beta1,
            beta2: train.beta2,
            eps: train.eps,
            weight_decay: train.weight_decay,
        };
        let fwd_plan: Vec<TensorDesc> =
            state.inv.iter().filter(|t| t.offloadable()).cloned().collect();
        let block_names = rt.manifest().block_weight_names.clone();
        let scratch = Arc::new(F32Scratch::with_meter(
            engine.arena.clone(),
            engine.copy_meter.clone(),
        ));
        // governed runs continue the tuning trajectory where the
        // checkpoint left it (bit-identical either way — retunes only
        // resize disjoint-range I/O windows; this just skips
        // re-warming); static runs keep the spec's knobs
        let tuning = if train.governor && tiled {
            PipelineTuning {
                optim_tile_bytes: ck.tile_bytes.max(1),
                tile_depth: ck.tile_depth.max(1),
                prefetch_depth: ck.prefetch_depth.max(1),
                sched_lead_us: ck.sched_lead_us,
                act_host_budget: ck.act_host_budget,
            }
        } else {
            PipelineTuning {
                optim_tile_bytes: train.optim_tile_bytes,
                tile_depth: train.optim_tile_depth.max(1),
                prefetch_depth: train.prefetch_depth.max(1),
                sched_lead_us: train.prefetch_lead_us,
                act_host_budget: train.act_host_budget,
            }
        };
        let governor = (train.governor && tiled)
            .then(|| PipelineGovernor::new(governor_config(&train, tuning), tuning));
        let mut coalesced = coalesce_cfg
            .then(|| {
                CoalescedOptim::resume(
                    engine.nvme.as_ref(),
                    &state.offloaded,
                    train.optim_coalesce_bytes,
                )
            })
            .transpose()?;
        let fetch_groups = match (&mut coalesced, train.fetch_coalesce) {
            (Some(co), true) => {
                // the packed read streams are checkpointed state now
                // (shadow-paged like every other stream): reattach to
                // the committed extents instead of re-gathering, which
                // would write into the epoch's invisible shadow extent
                co.attach_fp16_streams(engine.nvme.as_ref())?;
                Some(Arc::new(FetchGroups::from_layout(&co.layout)))
            }
            _ => None,
        };
        // the recorded step profile is a performance hint, not state:
        // a journaled digest that no longer matches the stored blob
        // degrades to an empty store (the first pass re-records) —
        // never a resume error
        let profile = if train.prefetch_profile {
            let store = match ck.profile_digest {
                Some(want) => {
                    let key = crate::offload::prefetch::PROFILE_KEY;
                    if ckpt::stored_digest(engine.nvme.as_ref(), key)? == Some(want) {
                        ProfileStore::load(engine.nvme.as_ref())?.unwrap_or_default()
                    } else {
                        ctx.events.emit(Event {
                            job: ctx.job,
                            kind: EventKind::ResumeProfileDiverged,
                            detail: String::new(),
                        });
                        ProfileStore::new()
                    }
                }
                None => ProfileStore::new(),
            };
            Some(Arc::new(store))
        } else {
            None
        };
        let trainer = Self {
            rt,
            engine,
            spec,
            train,
            state,
            flat,
            scaler,
            corpus,
            hp,
            applied_steps: ck.applied_steps,
            steps_done: ck.steps_done,
            seed: ck.seed,
            journal,
            last_epoch: ck.epoch,
            fwd_plan,
            block_names,
            scratch,
            tuning,
            governor,
            coalesced,
            fetch_groups,
            profile,
            ctx,
            scrub_cursor: 0,
        };
        trainer.wire_robustness_sinks();
        Ok(trainer)
    }

    /// The pipeline window knobs the next step will run with (the
    /// governor's latest retune, or the spec's static values).
    pub fn tuning(&self) -> PipelineTuning {
        self.tuning
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Steps completed on this storage, across resumes.
    pub fn steps_done(&self) -> u64 {
        self.steps_done
    }

    /// Newest committed journal epoch (0 = none yet).
    pub fn journal_epoch(&self) -> u64 {
        self.last_epoch
    }

    /// Borrow a resident tensor as a stage argument — no staging copy
    /// (the seed's `.to_vec()` per block per pass is gone).
    fn resident_arg(&self, name: &str) -> ValueRef<'_> {
        self.state.resident[name].value()
    }

    /// Fetch options for one swapper pass, from the governed tuning:
    /// window depth always, plus coalesced groups and profile replay
    /// when configured.
    fn fetch_opts(&self) -> FetchOpts {
        let mut opts = FetchOpts::window(self.tuning.prefetch_depth).for_job(self.ctx.job);
        if let Some(g) = &self.fetch_groups {
            opts = opts.with_groups(Arc::clone(g));
        }
        if let Some(p) = &self.profile {
            opts = opts.with_profile(Arc::clone(p), self.tuning.sched_lead_us);
        }
        opts
    }

    /// One full training step over all (simulated) ranks.
    pub fn step(&mut self, step_idx: u64) -> anyhow::Result<StepMetrics> {
        let t_step = Instant::now();
        let io_before = self.engine.nvme.stats();
        let copies_before = self.engine.copy_meter.bytes();
        let health = Arc::clone(self.engine.ioq.health());
        let hedges_before = health.hedges();
        let timeouts_before = health.timeouts();
        let scale = self.scaler.scale();
        let mut loss_sum = 0.0f64;
        let mut io_wait_secs = 0.0f64;
        let mut fetch_submissions = 0u64;
        let mut prefetch_hits = 0u64;
        let mut prefetch_late = 0u64;
        let mut prefetch_fallbacks = 0u64;
        let ranks = self.train.ranks.max(1);
        let l = self.spec.layers;
        let (b, s, h) = (self.train.batch, self.train.seq, self.spec.hidden);

        for _rank in 0..ranks {
            let (tokens, labels) = self.corpus.next_batch(b, s);

            // ---- forward (weights streamed by the swapper pipeline) ----
            let mut sw = Swapper::start(
                self.engine.nvme.clone(),
                self.engine.pool.clone(),
                self.engine.ioq.clone(),
                self.engine.stage.clone(),
                self.scratch.clone(),
                self.fwd_plan.clone(),
                |t| fp16_key(&t.name),
                self.fetch_opts(),
            );
            let table = sw.next()?; // embed — a lease-backed view
            let args = [ValueRef::I32(&tokens), table.data.as_value()];
            let mut hbuf = self.rt.run("embed_fwd", &args)?.remove(0).into_f32()?;
            self.scratch.put_buf(table.data);

            let mut ckpts = SpillingActivationStore::new(
                l,
                b * s * h,
                self.tuning.act_host_budget,
                self.engine.arena.clone(),
                self.engine.async_io(),
                self.engine.copy_meter.clone(),
            );
            for layer in 0..l {
                let mut ws: HashMap<String, TensorBuf> = HashMap::new();
                for _ in 0..7 {
                    let f = sw.next()?;
                    ws.insert(f.desc.name.clone(), f.data);
                }
                ckpts.offload(layer, &hbuf)?;
                let args = self.block_args(layer, &ws, &hbuf, None)?;
                let out = self.rt.run("block_fwd", &args)?.remove(0).into_f32()?;
                drop(args);
                self.scratch.put(std::mem::replace(&mut hbuf, out));
                for w in ws.into_values() {
                    self.scratch.put_buf(w); // views drop (extent recycles)
                }
            }

            // ---- head: fused linear + CE, fwd+bwd ----
            let head = sw.next()?; // lm_head
            let scale_arg = [scale as f32];
            let args = [
                ValueRef::F32(&hbuf),
                self.resident_arg("final_norm"),
                head.data.as_value(),
                ValueRef::I32(&labels),
                ValueRef::F32(&scale_arg),
            ];
            let mut out = self.rt.run("head_fwd_bwd", &args)?;
            self.scratch.put_buf(head.data);
            self.scratch.put(hbuf);
            let loss = out.remove(0).into_f32()?[0] as f64;
            let mut dh = out.remove(0).into_f32()?;
            let d_final_norm = out.remove(0).into_f32()?;
            let d_head = out.remove(0).into_f32()?;
            loss_sum += loss;
            self.accumulate("final_norm", &d_final_norm);
            self.accumulate("lm_head", &d_head);
            self.scratch.put(d_final_norm);
            self.scratch.put(d_head);
            io_wait_secs += sw.wait_secs();
            let swm = sw.metrics();
            fetch_submissions += swm.fetch_submissions;
            prefetch_hits += swm.prefetch_hits;
            prefetch_late += swm.prefetch_late;
            prefetch_fallbacks += u64::from(swm.profile_fallback);
            drop(sw);

            // ---- backward: blocks in reverse, weights re-streamed ----
            let bwd_plan: Vec<TensorDesc> = self
                .fwd_plan
                .iter()
                .filter(|t| t.layer != usize::MAX)
                .rev()
                .cloned()
                .collect();
            let mut swb = Swapper::start(
                self.engine.nvme.clone(),
                self.engine.pool.clone(),
                self.engine.ioq.clone(),
                self.engine.stage.clone(),
                self.scratch.clone(),
                bwd_plan,
                |t| fp16_key(&t.name),
                self.fetch_opts(),
            );
            for layer in (0..l).rev() {
                let mut ws: HashMap<String, TensorBuf> = HashMap::new();
                for _ in 0..7 {
                    let f = swb.next()?;
                    ws.insert(f.desc.name.clone(), f.data);
                }
                let h_in = ckpts.fetch(layer)?; // lease-backed view
                let args = self.block_args(layer, &ws, h_in.as_f32(), Some(&dh))?;
                let mut grads = self.rt.run("block_bwd", &args)?;
                drop(args);
                self.scratch.put_buf(h_in);
                self.scratch
                    .put(std::mem::replace(&mut dh, grads.remove(0).into_f32()?));
                // results follow BLOCK_WEIGHT_NAMES order (resolved once
                // at construction)
                for name in &self.block_names {
                    let g = grads.remove(0).into_f32()?;
                    accumulate_into(
                        &mut self.flat,
                        self.train.precision,
                        &format!("layers.{layer}.{name}"),
                        &g,
                    );
                    self.scratch.put(g);
                }
                for w in ws.into_values() {
                    self.scratch.put_buf(w);
                }
            }
            io_wait_secs += swb.wait_secs();
            let swm = swb.metrics();
            fetch_submissions += swm.fetch_submissions;
            prefetch_hits += swm.prefetch_hits;
            prefetch_late += swm.prefetch_late;
            prefetch_fallbacks += u64::from(swm.profile_fallback);
            drop(swb);
            // spill-fetch stalls the prefetch could not hide (the rest
            // of the spill I/O ran on the queue behind compute)
            io_wait_secs += ckpts.wait_secs();

            // ---- embedding backward ----
            let args = [ValueRef::I32(&tokens), ValueRef::F32(&dh)];
            let mut out = self.rt.run("embed_bwd", &args)?;
            self.scratch.put(dh);
            let d_table = out.remove(0).into_f32()?;
            self.accumulate("embed", &d_table);
            self.scratch.put(d_table);
        }

        // ---- overflow check over the fp32 flat buffer ----
        let t_ovf = Instant::now();
        let overflowed = self.engine.check_overflow(self.flat.as_slice());
        let overflow_check_secs = t_ovf.elapsed().as_secs_f64();
        let skip = self.scaler.update(overflowed);

        // ---- optimizer: SSD-swapped AdamW per tensor group ----
        let t_opt = Instant::now();
        let mut optim_tiles = 0u64;
        let mut degraded_tiles = 0u64;
        if !skip {
            self.applied_steps += 1;
            let t = self.applied_steps;
            let unscale = (scale * ranks as f64) as f32;
            if self.train.io_workers > 0 {
                // staged-tile pipeline (fixed-byte tiles, conversions
                // on the compute-side stage pool, peak pinned staging
                // independent of group size), over coalesced
                // super-group streams when configured; tile size and
                // depth come from the governed tuning.
                // optim_tile_bytes = 0 degrades to the whole-group
                // double-buffer inside
                let aio = self.engine.async_io();
                let grads: Vec<&[f32]> = self
                    .state
                    .offloaded
                    .iter()
                    .map(|st| self.flat.grads_of(&st.group))
                    .collect();
                let keys: Vec<String> = self
                    .state
                    .offloaded
                    .iter()
                    .map(|st| fp16_key(&st.group))
                    .collect();
                let stats = if let Some(co) = &self.coalesced {
                    co.step_tiled(
                        &aio,
                        &self.engine.stage,
                        &self.engine.arena,
                        &grads,
                        &keys,
                        t,
                        unscale,
                        &self.hp,
                        self.engine.threads,
                        self.tuning.optim_tile_bytes,
                        self.tuning.tile_depth,
                    )?
                } else {
                    crate::optimizer::step_groups_tiled(
                        &aio,
                        &self.engine.stage,
                        &self.engine.arena,
                        &self.state.offloaded,
                        &grads,
                        &keys,
                        t,
                        unscale,
                        &self.hp,
                        self.engine.threads,
                        self.tuning.optim_tile_bytes,
                        self.tuning.tile_depth,
                    )?
                };
                io_wait_secs += stats.wait_secs;
                optim_tiles = stats.tiles;
                degraded_tiles = stats.degraded_tiles;
            } else {
                // sequential reference: every optimizer byte is
                // foreground stall
                let opt_io_before = self.engine.nvme.stats();
                for st in &self.state.offloaded {
                    let grads = self.flat.grads_of(&st.group);
                    st.step(
                        self.engine.nvme.as_ref(),
                        grads,
                        t,
                        unscale,
                        &self.hp,
                        self.engine.threads,
                        &fp16_key(&st.group),
                    )?;
                }
                let opt_io_after = self.engine.nvme.stats();
                // sequential loop: every engine-busy second is stall
                io_wait_secs +=
                    (opt_io_after.busy_ns - opt_io_before.busy_ns) as f64 / 1e9;
            }
            for rt_tensor in self.state.resident.values_mut() {
                let (off, len) = self.flat.span_of(&rt_tensor.desc.name).unwrap();
                let grads = &self.flat.as_slice()[off..off + len];
                crate::optimizer::adam_step_f32(
                    &mut rt_tensor.data,
                    grads,
                    &mut rt_tensor.m,
                    &mut rt_tensor.v,
                    t,
                    unscale,
                    &self.hp,
                    1,
                );
            }
            // the first applied step after a commit wrote every state
            // key's update to its *shadow* extent (the committed epoch
            // stayed untouched); fold the map forward so the next step
            // reads back what this one wrote.  Skipped overflow steps
            // write nothing, so nothing is dirty and this is a no-op.
            self.engine.shadow.advance();
        }
        let optim_secs = t_opt.elapsed().as_secs_f64();
        self.flat.zero();

        let io_after = self.engine.nvme.stats();
        // union-of-busy-intervals: exact engine-busy wall time even
        // when the queue layer overlaps transfers
        let io_secs = (io_after.busy_ns - io_before.busy_ns) as f64 / 1e9;
        let step_secs = t_step.elapsed().as_secs_f64();
        let m = StepMetrics {
            step: step_idx,
            loss: loss_sum / ranks as f64,
            loss_scale: scale,
            overflowed,
            tokens: self.train.tokens_per_step(),
            step_secs,
            compute_secs: (step_secs - io_secs - overflow_check_secs - optim_secs).max(0.0),
            io_secs,
            overflow_check_secs,
            optim_secs,
            io_wait_secs,
            optim_tiles,
            degraded_tiles,
            nvme_submissions: io_after.ops() - io_before.ops(),
            optim_tile_bytes: self.tuning.optim_tile_bytes,
            tile_depth: self.tuning.tile_depth,
            prefetch_depth: self.tuning.prefetch_depth,
            host_copy_bytes: self.engine.copy_meter.bytes() - copies_before,
            // checkpoints run between steps ([`Self::run`] stamps the
            // cost in after the commit); 0.0 = no commit after this step
            ckpt_secs: 0.0,
            io_retries: io_after.retries - io_before.retries,
            journal_epoch: self.last_epoch,
            fetch_submissions,
            prefetch_hits,
            prefetch_late,
            prefetch_fallbacks,
            io_hedges: health.hedges() - hedges_before,
            io_timeouts: health.timeouts() - timeouts_before,
            integrity_failures: io_after.integrity_failures - io_before.integrity_failures,
            // scrub runs between steps ([`Self::run`]), so a step's
            // delta covers the walk that preceded it
            scrubbed_bytes: io_after.scrubbed_bytes - io_before.scrubbed_bytes,
            scrub_failures: io_after.scrub_failures - io_before.scrub_failures,
        };
        self.steps_done = step_idx;
        // close the feedback loop: the governor sees exactly what the
        // step report says, plus the arena's reserved/budget state
        let arena_stats = self.engine.arena.stats();
        let sample = GovernorSample {
            host_copy_bytes: m.host_copy_bytes,
            degraded_tiles: m.degraded_tiles,
            prefetch_late: m.prefetch_late,
            prefetch_hits: m.prefetch_hits,
            io_wait_secs: m.io_wait_secs,
            io_busy_secs: m.io_secs,
            step_secs: m.step_secs,
            arena_reserved: arena_stats.reserved_bytes,
            arena_budget: self.engine.arena.budget_bytes(),
            device_degraded: health.is_degraded(),
        };
        if let Some(gov) = &mut self.governor {
            self.tuning = gov.observe(&sample);
        }
        // fleet arbitration rides the same sample: caps overlay the
        // governed tuning (read-time clamp — lifted caps restore the
        // converged state exactly); static runs clamp the spec's knobs
        if let Some(fleet) = self.ctx.fleet.clone() {
            let caps = fleet.report(self.ctx.job, &sample);
            match &mut self.governor {
                Some(gov) => {
                    gov.set_caps(caps);
                    self.tuning = gov.tuning();
                }
                None => {
                    let base = PipelineTuning {
                        optim_tile_bytes: self.train.optim_tile_bytes,
                        tile_depth: self.train.optim_tile_depth.max(1),
                        prefetch_depth: self.train.prefetch_depth.max(1),
                        sched_lead_us: self.train.prefetch_lead_us,
                        act_host_budget: self.train.act_host_budget,
                    };
                    self.tuning = match caps {
                        Some(c) => c.clamp(base),
                        None => base,
                    };
                }
            }
        }
        Ok(m)
    }

    /// Build one block stage's argument list entirely from borrows:
    /// the hidden state, the fetched weight views (lease bytes upload
    /// verbatim — zero fp32 copies on the hot path), and the resident
    /// norms in place.
    fn block_args<'a>(
        &'a self,
        layer: usize,
        ws: &'a HashMap<String, TensorBuf>,
        h: &'a [f32],
        d_out: Option<&'a [f32]>,
    ) -> anyhow::Result<Vec<ValueRef<'a>>> {
        let p = |n: &str| format!("layers.{layer}.{n}");
        let w = |n: &str| -> anyhow::Result<ValueRef<'a>> {
            Ok(ws
                .get(&p(n))
                .ok_or_else(|| anyhow::anyhow!("missing weight {}", p(n)))?
                .as_value())
        };
        let mut args = vec![
            ValueRef::F32(h),
            self.resident_arg(&p("attn_norm")),
            w("wq")?,
            w("wk")?,
            w("wv")?,
            w("wo")?,
            self.resident_arg(&p("ffn_norm")),
            w("w_gate")?,
            w("w_up")?,
            w("w_down")?,
        ];
        if let Some(d) = d_out {
            args.push(ValueRef::F32(d));
        }
        Ok(args)
    }

    fn accumulate(&mut self, tensor: &str, grads: &[f32]) {
        accumulate_into(&mut self.flat, self.train.precision, tensor, grads);
    }

    /// Drain/shutdown durability point: flush every optimizer-state
    /// stream (master/m/v) and fp16 compute copy via
    /// [`crate::ssd::NvmeEngine::flush`].  Ranged tile writes never
    /// fsync per step (state is rebuilt on restart), so this is where
    /// buffered optimizer-state writes reach a defined durable state;
    /// [`Self::run`] calls it after the last step, and embedders can
    /// call it directly on shutdown or before a checkpoint.
    pub fn drain(&self) -> anyhow::Result<()> {
        let keys: Vec<String> =
            self.state.offloaded.iter().map(|st| fp16_key(&st.group)).collect();
        match &self.coalesced {
            // coalesced runs: state lives in the super-group streams
            Some(co) => co.flush(self.engine.nvme.as_ref(), &keys),
            None => crate::optimizer::flush_groups(
                self.engine.nvme.as_ref(),
                &self.state.offloaded,
                &keys,
            ),
        }
    }

    /// One idle-time scrub increment (`TrainSpec::scrub`): re-read a
    /// couple of this trainer's streams through the full stack so the
    /// integrity layer re-verifies their checksums, advancing a
    /// round-robin cursor over [`Self::shadow_key_set`].  Reads route
    /// through the shadow layer (each key's *live* extent) and heal
    /// transient corruption via the retry layer like any foreground
    /// fetch; durable rot is counted ([`StepMetrics::scrub_failures`])
    /// and reported through the integrity layer's event sink rather
    /// than aborting training — the stream may never be fetched again
    /// (or may be overwritten first), so the operator decides.
    fn scrub_tick(&mut self) -> anyhow::Result<()> {
        const KEYS_PER_TICK: usize = 2;
        let Some(integrity) = self.engine.integrity.clone() else {
            return Ok(());
        };
        let keys = self.shadow_key_set();
        if keys.is_empty() {
            return Ok(());
        }
        for _ in 0..KEYS_PER_TICK.min(keys.len()) {
            let key = &keys[self.scrub_cursor % keys.len()];
            self.scrub_cursor = (self.scrub_cursor + 1) % keys.len();
            // a key can be registered but not yet written (e.g. a
            // stream that only materializes on the first applied step)
            let Some(len) = self.engine.nvme.len_of(key) else {
                continue;
            };
            let mut buf = vec![0u8; len];
            let ok = self.engine.nvme.read(key, &mut buf).is_ok();
            integrity.note_scrub(len as u64, ok);
        }
        Ok(())
    }

    /// Optimizer-state dtype label as journaled ("f32" | "bf16").
    fn dtype_label(&self) -> &'static str {
        match self.train.optim_dtype {
            crate::dtype::DType::BF16 => "bf16",
            _ => "f32",
        }
    }

    /// Every logical stream one checkpoint epoch shadow-pages: the
    /// optimizer state streams (super-group or per-tensor), the packed
    /// fp16 read streams when fetch coalescing mirrors them, every
    /// per-tensor fp16 compute copy, and the resident-tensor blobs in
    /// sorted order.  The coalesce-layout blob is deliberately *not*
    /// here — it is immutable once laid, so one physical extent serves
    /// every epoch.
    fn shadow_key_set(&self) -> Vec<String> {
        let mut keys: Vec<String> = Vec::new();
        match &self.coalesced {
            // coalesced runs: state lives in the super-group streams
            // (member state streams are stale by design)
            Some(co) => {
                for st in &co.supers {
                    keys.extend(crate::optimizer::states::state_keys(&st.group));
                }
                if co.fp16_streams_enabled() {
                    for i in 0..co.supers.len() {
                        keys.push(crate::optimizer::coalesce::fp16_stream_name(i));
                    }
                }
            }
            None => {
                for st in &self.state.offloaded {
                    keys.extend(crate::optimizer::states::state_keys(&st.group));
                }
            }
        }
        for st in &self.state.offloaded {
            keys.push(fp16_key(&st.group));
        }
        let mut resident: Vec<&String> = self.state.resident.keys().collect();
        resident.sort();
        for name in resident {
            keys.push(ckpt::resident_key(name));
        }
        keys
    }

    /// Every on-SSD key one checkpoint epoch covers, with stored
    /// lengths and the physical extent holding this epoch's bytes.
    /// Called after the flush barriers, so a missing key is a
    /// commit-time invariant violation, not a race.
    fn ckpt_keys(&self) -> anyhow::Result<Vec<(String, usize, u8)>> {
        let mut keys = self.shadow_key_set();
        if self.coalesced.is_some() {
            keys.push(crate::optimizer::coalesce::LAYOUT_KEY.to_string());
        }
        keys.into_iter()
            .map(|k| {
                // resolve length on the *physical* extent the record
                // will name, so the journaled (len, ext) pair always
                // describes the same bytes
                let ext = self.engine.shadow.newest_ext(&k);
                let len = self
                    .engine
                    .shadow
                    .inner()
                    .len_of(&ckpt::phys_key(&k, ext))
                    .ok_or_else(|| {
                        anyhow::anyhow!("checkpoint key '{k}' missing at commit time")
                    })?;
                Ok((k, len, ext))
            })
            .collect()
    }

    /// Commit one checkpoint epoch: flush barriers over every state and
    /// fp16 stream ([`Self::drain`]), persist the host-resident tensors
    /// and cursors, atomically advance the journal, then flip the
    /// shadow map so the next window's write-backs target the *other*
    /// physical extent of every stream — the epoch just committed (and
    /// the one before it) stay recoverable through any later crash.
    /// Returns the elapsed seconds; [`Self::run`] surfaces them as
    /// [`StepMetrics::ckpt_secs`], a durability tax deliberately kept
    /// out of `io_wait_secs`.
    pub fn checkpoint(&mut self) -> anyhow::Result<f64> {
        let t0 = Instant::now();
        // 1. barrier: buffered ranged writes reach a defined durable
        //    state on every stream the epoch will name (flush routes to
        //    each key's newest extent — the one the record will carry)
        self.drain()?;
        // 2. the only byte-moving part: resident tensors (norms) and
        //    their Adam state, checksummed, in sorted order for
        //    determinism; flushed so the slot write never races them
        let mut names: Vec<&String> = self.state.resident.keys().collect();
        names.sort();
        for name in names {
            let rt = &self.state.resident[name];
            ckpt::write_resident(self.engine.nvme.as_ref(), name, &rt.data, &rt.m, &rt.v)?;
            self.engine.nvme.flush(&ckpt::resident_key(name))?;
        }
        let layout_digest = match &self.coalesced {
            Some(_) => {
                let key = crate::optimizer::coalesce::LAYOUT_KEY;
                self.engine.nvme.flush(key)?;
                ckpt::stored_digest(self.engine.nvme.as_ref(), key)?
            }
            None => None,
        };
        // the recorded step profiles ride the epoch too, so a resumed
        // run replays its warmed schedule instead of re-recording
        let profile_digest = match &self.profile {
            Some(store) => {
                if store.dirty() {
                    store.persist(self.engine.nvme.as_ref())?;
                }
                ckpt::stored_digest(
                    self.engine.nvme.as_ref(),
                    crate::offload::prefetch::PROFILE_KEY,
                )?
            }
            None => None,
        };
        // 3. atomic journal advance — data is durable first, so a
        //    visible record always describes state that exists
        let (scale, good_steps, overflows, growths) = self.scaler.snapshot();
        let ck = CkptState {
            epoch: self.last_epoch + 1,
            steps_done: self.steps_done,
            applied_steps: self.applied_steps,
            seed: self.seed,
            model: self.spec.name.to_string(),
            dtype: self.dtype_label().to_string(),
            corpus_rng: self.corpus.rng_state(),
            scale,
            good_steps,
            overflows,
            growths,
            tile_bytes: self.tuning.optim_tile_bytes,
            tile_depth: self.tuning.tile_depth,
            prefetch_depth: self.tuning.prefetch_depth,
            sched_lead_us: self.tuning.sched_lead_us,
            act_host_budget: self.tuning.act_host_budget,
            keys: self.ckpt_keys()?,
            layout_digest,
            profile_digest,
        };
        self.journal.commit(&ck)?;
        self.last_epoch = ck.epoch;
        // 4. flip: route the next window's write-backs to the other
        //    physical extent of every stream.  In-memory only — if we
        //    crash before any flipped write lands, the slot record just
        //    written is the durable authority and resume re-derives the
        //    same routing from its extent map.
        self.engine.shadow.flip();
        Ok(t0.elapsed().as_secs_f64())
    }

    /// Run `opts.steps` steps, returning the full report.
    pub fn run(&mut self, opts: &TrainOpts) -> anyhow::Result<RunReport> {
        let mut report = RunReport {
            label: self.train.flags.label(),
            model: self.spec.name.to_string(),
            ..Default::default()
        };
        let interval = self.train.ckpt_interval_steps as u64;
        for i in 0..opts.steps {
            // number steps across resumes: a resumed run continues at
            // `steps_done + 1`, not 1
            let idx = self.steps_done + 1;
            let mut m = self.step(idx)?;
            if interval > 0 && idx % interval == 0 {
                m.ckpt_secs = self
                    .checkpoint()
                    .map_err(|e| e.context(format!("checkpoint commit failed after step {idx}")))?;
                m.journal_epoch = self.last_epoch;
            }
            // idle-time integrity scrub between steps; the bytes it
            // verifies land in the *next* step's scrub deltas
            if self.train.scrub {
                self.scrub_tick()?;
            }
            if opts.log_every > 0 && (i + 1) % opts.log_every == 0 {
                let mut extra = String::new();
                if m.io_retries > 0 {
                    extra.push_str(&format!("  io-retries {}", m.io_retries));
                }
                if interval > 0 {
                    extra.push_str(&format!("  epoch {}", m.journal_epoch));
                    if m.ckpt_secs > 0.0 {
                        extra.push_str(&format!("  ckpt {:.2}s", m.ckpt_secs));
                    }
                }
                log::info!(
                    "step {:>4}  loss {:.4}  scale {:>8}  {:.2}s ({} tok/s){extra}",
                    m.step,
                    m.loss,
                    m.loss_scale,
                    m.step_secs,
                    (m.tokens as f64 / m.step_secs) as u64
                );
                eprintln!(
                    "[{}] step {:>4}  loss {:.4}  scale {}  {:.2}s{extra}",
                    report.label, m.step, m.loss, m.loss_scale, m.step_secs
                );
            }
            report.steps.push(m);
        }
        report.peak_sysmem_bytes = self.engine.tracker.peak_total();
        let io = self.engine.nvme.stats();
        report.io_bytes_per_step = io.total_bytes() / opts.steps.max(1) as u64;
        if let Some(path) = &opts.loss_csv {
            report.write_loss_csv(path)?;
        }
        // one explicit durability point after the run's buffered
        // ranged writes (the per-step loop pays no durability tax).
        // The report is assembled — and the loss CSV written — first,
        // so a flush failure loses durability, not the completed run's
        // metrics on disk.
        self.drain().map_err(|e| {
            e.context(format!(
                "optimizer-state drain failed after {} completed steps",
                opts.steps
            ))
        })?;
        Ok(report)
    }
}

/// Gradient accumulation over the flat buffer, free-standing so the
/// backward loop can iterate `block_names` (shared borrow) while
/// writing `flat` (mutable borrow) — disjoint fields of the trainer.
fn accumulate_into(
    flat: &mut GradFlatBuffer,
    precision: crate::config::Precision,
    tensor: &str,
    grads: &[f32],
) {
    match precision {
        crate::config::Precision::MixedF16 => flat.accumulate_f16_transport(tensor, grads),
        crate::config::Precision::MixedBF16 => flat.accumulate_bf16_transport(tensor, grads),
    }
}
