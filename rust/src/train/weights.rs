//! Weight initialization: populate the SSD with the model's fp16
//! compute weights and optimizer states, and keep the small resident
//! tensors (norms) in host memory.
//!
//! Deterministic by seed — the loss-parity test requires baseline and
//! MemAscend runs to start from bit-identical weights.

use std::collections::HashMap;

use crate::config::ModelSpec;
use crate::optimizer::{OptimState, StateDtype};
use crate::runtime::ValueRef;
use crate::ssd::NvmeEngine;
use crate::tensors::{inventory, Category, TensorDesc};
use crate::util::rng::Xoshiro256;

/// Resident (never-offloaded) tensor with in-memory optimizer state.
pub struct ResidentTensor {
    pub desc: TensorDesc,
    pub data: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

impl ResidentTensor {
    /// Borrow the resident fp32 data as a PJRT argument — the
    /// replacement for the seed's per-call `.to_vec()` staging copy
    /// (one full norm-tensor memcpy per block per pass).
    pub fn value(&self) -> ValueRef<'_> {
        ValueRef::F32(&self.data)
    }
}

pub struct ModelState {
    /// SSD-resident tensors' optimizer handles, in inventory order.
    pub offloaded: Vec<OptimState>,
    /// name -> resident tensor (norms).
    pub resident: HashMap<String, ResidentTensor>,
    /// Inventory in canonical order.
    pub inv: Vec<TensorDesc>,
}

pub fn fp16_key(name: &str) -> String {
    format!("{name}/fp16")
}

fn init_values(t: &TensorDesc, rng: &mut Xoshiro256) -> Vec<f32> {
    match t.category {
        Category::Norm => vec![1.0; t.numel],
        Category::Embedding | Category::LmHead => {
            let mut v = vec![0f32; t.numel];
            rng.fill_normal(&mut v, 0.02);
            v
        }
        _ => {
            let fan_in = t.shape[0] as f32;
            let mut v = vec![0f32; t.numel];
            rng.fill_normal(&mut v, 0.5 / fan_in.sqrt());
            v
        }
    }
}

/// Initialize all weights + optimizer states. Offloadable tensors land
/// on the SSD (fp16 compute + states via `OptimState::init`); norms
/// stay resident.
pub fn init_weights(
    spec: &ModelSpec,
    engine: &dyn NvmeEngine,
    state_dtype: StateDtype,
    seed: u64,
) -> anyhow::Result<ModelState> {
    let inv = inventory(spec);
    let mut offloaded = Vec::new();
    let mut resident = HashMap::new();
    let mut rng = Xoshiro256::new(seed);
    for t in &inv {
        let vals = init_values(t, &mut rng);
        if t.offloadable() {
            // fp16 compute copy on SSD
            let mut bytes = vec![0u8; t.numel * 2];
            crate::dtype::f32s_to_f16_bytes(&vals, &mut bytes);
            engine.write(&fp16_key(&t.name), &bytes)?;
            // master + m + v on SSD
            offloaded.push(OptimState::init(engine, &t.name, &vals, state_dtype)?);
        } else {
            resident.insert(
                t.name.clone(),
                ResidentTensor {
                    desc: t.clone(),
                    m: vec![0.0; vals.len()],
                    v: vec![0.0; vals.len()],
                    data: vals,
                },
            );
        }
    }
    Ok(ModelState { offloaded, resident, inv })
}

/// Rebuild a [`ModelState`] over storage that already holds the
/// weights — the checkpoint-resume path.  Writes nothing and consumes
/// no RNG: offloaded handles are pure metadata over the SSD streams
/// (the caller's journal check has already validated every stored key
/// length), and resident tensors read back from the `ckpt/resident/*`
/// blobs the last checkpoint persisted.  Peak DRAM cost is the norm
/// tensors only — optimizer state never re-stages through host memory.
pub fn resume_weights(
    spec: &ModelSpec,
    engine: &dyn NvmeEngine,
    state_dtype: StateDtype,
) -> anyhow::Result<ModelState> {
    let inv = inventory(spec);
    let mut offloaded = Vec::new();
    let mut resident = HashMap::new();
    for t in &inv {
        if t.offloadable() {
            offloaded.push(OptimState {
                group: t.name.clone(),
                numel: t.numel,
                dtype: state_dtype,
            });
        } else {
            let (data, m, v) = crate::ckpt::read_resident(engine, &t.name, t.numel)?;
            resident.insert(
                t.name.clone(),
                ResidentTensor { desc: t.clone(), data, m, v },
            );
        }
    }
    Ok(ModelState { offloaded, resident, inv })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::SMOKE;
    use crate::ssd::DirectEngine;

    #[test]
    fn init_populates_ssd_and_resident() {
        let dir = std::env::temp_dir().join(format!("ma-wi-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let eng = DirectEngine::new(&dir, 1, 1 << 24, 1).unwrap();
        let st = init_weights(&SMOKE, &eng, StateDtype::F32, 42).unwrap();
        // every offloadable tensor present on SSD with the right size
        for t in st.inv.iter().filter(|t| t.offloadable()) {
            assert_eq!(eng.len_of(&fp16_key(&t.name)), Some(t.numel * 2), "{}", t.name);
            assert_eq!(
                eng.len_of(&format!("{}/master", t.name)),
                Some(t.numel * 4)
            );
        }
        // norms resident, initialized to ones
        let norm = st.resident.get("layers.0.attn_norm").unwrap();
        assert!(norm.data.iter().all(|&x| x == 1.0));
        // the argument view borrows the resident storage itself
        let arg = norm.value();
        assert_eq!(arg.as_f32().unwrap().as_ptr(), norm.data.as_ptr());
        assert_eq!(arg.len(), norm.data.len());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn deterministic_by_seed() {
        let d1 = std::env::temp_dir().join(format!("ma-wd1-{}", std::process::id()));
        let d2 = std::env::temp_dir().join(format!("ma-wd2-{}", std::process::id()));
        std::fs::create_dir_all(&d1).unwrap();
        std::fs::create_dir_all(&d2).unwrap();
        let e1 = DirectEngine::new(&d1, 1, 1 << 24, 1).unwrap();
        let e2 = DirectEngine::new(&d2, 2, 1 << 24, 1).unwrap(); // different striping!
        init_weights(&SMOKE, &e1, StateDtype::F32, 7).unwrap();
        init_weights(&SMOKE, &e2, StateDtype::F32, 7).unwrap();
        let key = fp16_key("layers.1.wq");
        let n = e1.len_of(&key).unwrap();
        let mut a = vec![0u8; n];
        let mut b = vec![0u8; n];
        e1.read(&key, &mut a).unwrap();
        e2.read(&key, &mut b).unwrap();
        assert_eq!(a, b, "weights must not depend on engine layout");
        std::fs::remove_dir_all(&d1).ok();
        std::fs::remove_dir_all(&d2).ok();
    }
}
