//! Timing/bench harness (criterion is unavailable offline).
//!
//! `cargo bench` targets are `harness = false` binaries built on this:
//! warmup, fixed-iteration or fixed-duration sampling, and robust
//! statistics (mean/p50/p99/min).  Used both by benches/ and by the
//! §Perf optimization loop.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<Duration>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_unstable();
        let n = samples.len();
        let sum: Duration = samples.iter().sum();
        let pick = |q: f64| samples[((n - 1) as f64 * q).round() as usize];
        Stats {
            iters: n,
            mean: sum / n as u32,
            p50: pick(0.50),
            p99: pick(0.99),
            min: samples[0],
            max: samples[n - 1],
        }
    }

    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    /// Throughput in units/s given per-iteration work.
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:>10.3?}  p50 {:>10.3?}  p99 {:>10.3?}  min {:>10.3?}  (n={})",
            self.mean, self.p50, self.p99, self.min, self.iters
        )
    }
}

/// Benchmark `f`, auto-scaling iteration count to fill `budget`.
pub fn bench<F: FnMut()>(warmup: usize, budget: Duration, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    // estimate cost with one timed call
    let t0 = Instant::now();
    f();
    let est = t0.elapsed().max(Duration::from_nanos(100));
    let mut samples = vec![est];
    let target = (budget.as_secs_f64() / est.as_secs_f64()).clamp(1.0, 10_000.0) as usize;
    for _ in 0..target {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    Stats::from_samples(samples)
}

/// Benchmark with a fixed number of iterations (for expensive bodies).
pub fn bench_n<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    Stats::from_samples(samples)
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Pretty table printer for paper-vs-measured rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Self { headers: headers.into_iter().map(Into::into).collect(), rows: vec![] }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        self.rows.push(cells.into_iter().map(Into::into).collect());
    }

    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate().take(cols) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate().take(cols) {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s.push('\n');
            s
        };
        let mut out = line(&self.headers);
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&line(r));
        }
        out
    }

    /// Also dump as CSV for plotting.
    pub fn to_csv(&self) -> String {
        let esc = |c: &String| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        };
        let mut out = self.headers.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = Stats::from_samples(
            (1..=100).map(Duration::from_micros).collect(),
        );
        assert!(s.min <= s.p50 && s.p50 <= s.p99 && s.p99 <= s.max);
        assert_eq!(s.iters, 100);
    }

    #[test]
    fn bench_runs() {
        let mut acc = 0u64;
        let s = bench(1, Duration::from_millis(5), || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(s.iters >= 2);
    }

    #[test]
    fn table_render_and_csv() {
        let mut t = Table::new(vec!["model", "paper", "measured"]);
        t.row(vec!["qwen7b", "109.04", "43.6"]);
        let r = t.render();
        assert!(r.contains("qwen7b"));
        assert!(t.to_csv().starts_with("model,paper,measured\n"));
    }
}
