//! Minimal subcommand/flag CLI parser (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional args, with generated help text.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_bool: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    pub positionals: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .replace('_', "")
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected number, got '{v}'")),
        }
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some(""))
    }
}

pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub args: Vec<ArgSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, args: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec { name, help, default: None, is_bool: true });
        self
    }

    pub fn opt(
        mut self,
        name: &'static str,
        default: &'static str,
        help: &'static str,
    ) -> Self {
        self.args
            .push(ArgSpec { name, help, default: Some(default), is_bool: false });
        self
    }

    pub fn parse(&self, argv: &[String]) -> anyhow::Result<Args> {
        let mut out = Args::default();
        for spec in &self.args {
            if let Some(d) = spec.default {
                out.values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .args
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| {
                        anyhow::anyhow!("unknown flag --{key}\n\n{}", self.help())
                    })?;
                let value = if let Some(v) = inline {
                    v
                } else if spec.is_bool {
                    "true".to_string()
                } else {
                    i += 1;
                    argv.get(i)
                        .ok_or_else(|| anyhow::anyhow!("--{key} needs a value"))?
                        .clone()
                };
                out.values.insert(key, value);
            } else {
                out.positionals.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for a in &self.args {
            let def = a
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{:<24} {}{}\n", a.name, a.help, def));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("train", "train a model")
            .opt("steps", "100", "number of steps")
            .opt("model", "smoke", "model config")
            .flag("memascend", "enable all MemAscend optimizations")
    }

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&sv(&[])).unwrap();
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert_eq!(a.get("model"), Some("smoke"));
        assert!(!a.get_bool("memascend"));
    }

    #[test]
    fn parse_forms() {
        let a = cmd()
            .parse(&sv(&["--steps", "5", "--model=tiny25m", "--memascend", "pos"]))
            .unwrap();
        assert_eq!(a.get_usize("steps", 0).unwrap(), 5);
        assert_eq!(a.get("model"), Some("tiny25m"));
        assert!(a.get_bool("memascend"));
        assert_eq!(a.positionals, vec!["pos"]);
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(cmd().parse(&sv(&["--nope"])).is_err());
    }

    #[test]
    fn underscore_numbers() {
        let a = cmd().parse(&sv(&["--steps", "1_000"])).unwrap();
        assert_eq!(a.get_usize("steps", 0).unwrap(), 1000);
    }
}
