//! Structured, job-attributable diagnostic events.
//!
//! The single-trainer stack reported rare conditions (skipped resume
//! epochs, profile-digest divergence) with bare `eprintln!`.  Under
//! multi-job tenancy those lines are useless — nothing says *which*
//! job walked back an epoch — and tests cannot assert on them.  This
//! module replaces them with a tiny event bus:
//!
//! - [`JobId`] names a tenant.  `JobId::HOST` (0) is the implicit
//!   single-job default; every pre-tenancy call site maps to it, so
//!   solo runs behave exactly as before.
//! - [`Event`] is one diagnostic occurrence: the owning job, a typed
//!   [`EventKind`], and a human-readable detail string.
//! - [`EventSink`] is where events go.  [`StderrSink`] preserves the
//!   historical `eprintln!` text (prefixed with the job for non-host
//!   tenants); [`MemorySink`] records events for test assertions.
//!
//! The sink is deliberately synchronous and allocation-light: events
//! fire on resume/error paths, not per step.

use std::sync::{Arc, Mutex};

/// Maximum number of per-job accounting lanes carried by fixed-size
/// snapshot arrays ([`crate::ssd::IoSnapshot`]).  Jobs with an id at
/// or above this share the last lane; scheduling weights and arena
/// namespaces are likewise clamped.
pub const MAX_JOB_LANES: usize = 8;

/// A tenant identifier.  `0` is the host/default job — the identity
/// of every pre-tenancy code path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u16);

impl JobId {
    /// The implicit single-job default: solo trainers, direct engine
    /// users, and every call site that predates tenancy.
    pub const HOST: JobId = JobId(0);

    /// The accounting/scheduling lane for this job.  Ids beyond
    /// [`MAX_JOB_LANES`] fold into the last lane.
    pub fn lane(self) -> usize {
        (self.0 as usize).min(MAX_JOB_LANES - 1)
    }
}

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "j{}", self.0)
    }
}

/// What happened.  Typed so tests match on structure, not strings.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EventKind {
    /// `Trainer::resume` found a journaled epoch that failed
    /// verification and walked back past it.
    ResumeEpochSkipped { epoch: u64 },
    /// The persisted step-profile blob diverged from its journaled
    /// digest; prefetch falls back to the depth window until a fresh
    /// profile records.
    ResumeProfileDiverged,
    /// A registry-managed job terminated with an error.
    JobFailed,
    /// A registry-managed job changed lifecycle state
    /// (paused/resumed/stopped).
    JobStateChanged { state: &'static str },
    /// A storage device's bad-op rate (errors + timeouts) crossed the
    /// quarantine threshold ([`crate::ssd::HealthTracker`]); the fleet
    /// and pipeline governors shrink depth/prefetch against it until
    /// [`EventKind::DeviceRecovered`].
    DeviceDegraded { errors: u64, timeouts: u64 },
    /// A quarantined device's clean-op cooldown completed; normal
    /// depth/prefetch resumes.
    DeviceRecovered,
    /// A checksummed stream read back with a block whose sum diverged
    /// from its sidecar ([`crate::ssd::IntegrityError`]); the retry
    /// layer re-reads, so one event per *detection*, not per abort.
    IntegrityViolation { key: String, block: usize },
}

impl EventKind {
    /// Stable machine-readable name (the `kind` field of the
    /// [`FileSink`] JSON-lines format).
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::ResumeEpochSkipped { .. } => "resume_epoch_skipped",
            EventKind::ResumeProfileDiverged => "resume_profile_diverged",
            EventKind::JobFailed => "job_failed",
            EventKind::JobStateChanged { .. } => "job_state_changed",
            EventKind::DeviceDegraded { .. } => "device_degraded",
            EventKind::DeviceRecovered => "device_recovered",
            EventKind::IntegrityViolation { .. } => "integrity_violation",
        }
    }
}

/// One diagnostic occurrence, attributable to a job.
#[derive(Debug, Clone)]
pub struct Event {
    pub job: JobId,
    pub kind: EventKind,
    /// Free-form detail (error chains, epoch context).
    pub detail: String,
}

/// Destination for [`Event`]s.  Shared between the registry and every
/// trainer, so implementations must be `Send + Sync`.
pub trait EventSink: Send + Sync {
    fn emit(&self, ev: Event);
}

/// Default sink: formats events the way the historical `eprintln!`
/// diagnostics did, with a `[jN]` prefix for non-host jobs.
pub struct StderrSink;

impl EventSink for StderrSink {
    fn emit(&self, ev: Event) {
        let who = if ev.job == JobId::HOST {
            String::new()
        } else {
            format!("[{}] ", ev.job)
        };
        match &ev.kind {
            EventKind::ResumeEpochSkipped { epoch } => {
                eprintln!(
                    "{who}[resume] epoch {epoch} is not recoverable ({}); walking back",
                    ev.detail
                );
            }
            EventKind::ResumeProfileDiverged => {
                eprintln!(
                    "{who}[resume] step-profile blob diverged from the journaled \
                     digest; re-recording (prefetch falls back to the depth \
                     window until then)"
                );
            }
            EventKind::JobFailed => {
                eprintln!("{who}[jobs] job failed: {}", ev.detail);
            }
            EventKind::JobStateChanged { state } => {
                eprintln!("{who}[jobs] state -> {state}");
            }
            EventKind::DeviceDegraded { errors, timeouts } => {
                eprintln!(
                    "{who}[health] device degraded ({errors} errors, {timeouts} \
                     timeouts): {} — quarantining until a clean cooldown",
                    ev.detail
                );
            }
            EventKind::DeviceRecovered => {
                eprintln!("{who}[health] device recovered: {}", ev.detail);
            }
            EventKind::IntegrityViolation { key, block } => {
                eprintln!(
                    "{who}[integrity] checksum mismatch on '{key}' block {block} ({})",
                    ev.detail
                );
            }
        }
    }
}

/// JSON-lines sink: one event per line, flushed per event, so chaos
/// soaks and `multitrain` runs leave a machine-readable stream that
/// survives a crash mid-run.  Line shape:
/// `{"job": N, "kind": "...", <kind fields...>, "detail": "..."}`.
pub struct FileSink {
    file: Mutex<std::fs::File>,
}

impl FileSink {
    /// Create (truncate) the stream at `path`, creating parent
    /// directories as needed.
    pub fn create(path: &str) -> anyhow::Result<Arc<Self>> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let file = std::fs::File::create(path)?;
        Ok(Arc::new(Self { file: Mutex::new(file) }))
    }
}

impl EventSink for FileSink {
    fn emit(&self, ev: Event) {
        use crate::util::json::Json;
        use std::io::Write;
        let mut fields: Vec<(&str, Json)> = vec![
            ("job", Json::from(ev.job.0 as u64)),
            ("kind", Json::from(ev.kind.name())),
        ];
        match &ev.kind {
            EventKind::ResumeEpochSkipped { epoch } => {
                fields.push(("epoch", Json::from(*epoch)));
            }
            EventKind::JobStateChanged { state } => {
                fields.push(("state", Json::from(*state)));
            }
            EventKind::DeviceDegraded { errors, timeouts } => {
                fields.push(("errors", Json::from(*errors)));
                fields.push(("timeouts", Json::from(*timeouts)));
            }
            EventKind::IntegrityViolation { key, block } => {
                fields.push(("key", Json::from(key.clone())));
                fields.push(("block", Json::from(*block)));
            }
            EventKind::ResumeProfileDiverged
            | EventKind::JobFailed
            | EventKind::DeviceRecovered => {}
        }
        fields.push(("detail", Json::from(ev.detail.clone())));
        let line = Json::obj(fields).to_string();
        let mut f = self.file.lock().unwrap();
        // an event stream that loses lines on crash is useless to the
        // chaos soaks, so flush per event (events fire on rare paths,
        // not per step)
        if writeln!(f, "{line}").is_ok() {
            let _ = f.flush();
        }
    }
}

/// Test sink: records every event for later assertion.
#[derive(Default)]
pub struct MemorySink {
    events: Mutex<Vec<Event>>,
}

impl MemorySink {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Snapshot of everything emitted so far.
    pub fn events(&self) -> Vec<Event> {
        self.events.lock().unwrap().clone()
    }

    /// Events attributed to one job.
    pub fn for_job(&self, job: JobId) -> Vec<Event> {
        self.events
            .lock()
            .unwrap()
            .iter()
            .filter(|e| e.job == job)
            .cloned()
            .collect()
    }
}

impl EventSink for MemorySink {
    fn emit(&self, ev: Event) {
        self.events.lock().unwrap().push(ev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_sink_records_and_filters_by_job() {
        let sink = MemorySink::new();
        sink.emit(Event {
            job: JobId(1),
            kind: EventKind::ResumeEpochSkipped { epoch: 7 },
            detail: "bad checksum".into(),
        });
        sink.emit(Event {
            job: JobId(2),
            kind: EventKind::ResumeProfileDiverged,
            detail: String::new(),
        });
        assert_eq!(sink.events().len(), 2);
        let j1 = sink.for_job(JobId(1));
        assert_eq!(j1.len(), 1);
        assert_eq!(j1[0].kind, EventKind::ResumeEpochSkipped { epoch: 7 });
        assert!(sink.for_job(JobId(3)).is_empty());
    }

    #[test]
    fn lanes_clamp_to_the_fixed_array() {
        assert_eq!(JobId::HOST.lane(), 0);
        assert_eq!(JobId(3).lane(), 3);
        assert_eq!(JobId(7).lane(), 7);
        assert_eq!(JobId(8).lane(), MAX_JOB_LANES - 1);
        assert_eq!(JobId(u16::MAX).lane(), MAX_JOB_LANES - 1);
    }

    #[test]
    fn display_is_compact() {
        assert_eq!(JobId(4).to_string(), "j4");
    }

    #[test]
    fn file_sink_writes_one_flushed_json_line_per_event() {
        use crate::util::json::Json;
        let path = std::env::temp_dir()
            .join(format!("ma-events-{}.jsonl", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let sink = FileSink::create(&path).unwrap();
        sink.emit(Event {
            job: JobId(2),
            kind: EventKind::IntegrityViolation { key: "master/w0".into(), block: 3 },
            detail: "expected 0badc0de".into(),
        });
        sink.emit(Event {
            job: JobId::HOST,
            kind: EventKind::DeviceDegraded { errors: 5, timeouts: 2 },
            detail: String::new(),
        });
        // flushed per event: readable without dropping the sink
        let raw = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = raw.lines().collect();
        assert_eq!(lines.len(), 2);
        let ev0 = Json::parse(lines[0]).unwrap();
        assert_eq!(ev0.get("kind").unwrap().as_str(), Some("integrity_violation"));
        assert_eq!(ev0.get("key").unwrap().as_str(), Some("master/w0"));
        let ev1 = Json::parse(lines[1]).unwrap();
        assert_eq!(ev1.get("kind").unwrap().as_str(), Some("device_degraded"));
        drop(sink);
        std::fs::remove_file(&path).ok();
    }
}
