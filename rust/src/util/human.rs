//! Human-readable formatting helpers (byte sizes, rates, durations).

pub const KIB: u64 = 1 << 10;
pub const MIB: u64 = 1 << 20;
pub const GIB: u64 = 1 << 30;

/// "13.05 GiB", "219.4 us", matching the units the paper reports.
pub fn bytes(n: u64) -> String {
    let f = n as f64;
    if n >= GIB {
        format!("{:.2} GiB", f / GIB as f64)
    } else if n >= MIB {
        format!("{:.2} MiB", f / MIB as f64)
    } else if n >= KIB {
        format!("{:.2} KiB", f / KIB as f64)
    } else {
        format!("{n} B")
    }
}

pub fn gib(n: u64) -> f64 {
    n as f64 / GIB as f64
}

pub fn rate(bytes_per_sec: f64) -> String {
    if bytes_per_sec >= GIB as f64 {
        format!("{:.2} GiB/s", bytes_per_sec / GIB as f64)
    } else if bytes_per_sec >= MIB as f64 {
        format!("{:.2} MiB/s", bytes_per_sec / MIB as f64)
    } else {
        format!("{:.2} KiB/s", bytes_per_sec / KIB as f64)
    }
}

pub fn secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

/// Percent delta "(-55.7%)" with sign.
pub fn pct_delta(base: f64, new: f64) -> String {
    if base == 0.0 {
        return "n/a".into();
    }
    format!("{:+.1}%", (new - base) / base * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(13 * GIB + 52 * MIB), "13.05 GiB");
        assert_eq!(secs(0.0055), "5.500 ms");
        assert_eq!(pct_delta(100.0, 44.3), "-55.7%");
    }
}
