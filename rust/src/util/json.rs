//! Minimal JSON parser/writer (serde_json is unavailable offline).
//!
//! Full JSON grammar: objects, arrays, strings (with escapes), numbers,
//! bools, null. Used for the AOT manifest, config files, and metric dumps.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that errors with the path, for manifest parsing.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut cp = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            cp = cp * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // collect the full utf-8 sequence starting at c
                    let len = utf8_len(c);
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"cfg":{"n":128,"name":"smoke"},"xs":[1,2.5,true,null,"s"]}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ✓"));
    }
}
