//! Minimal env-filtered logger behind the `log` facade (env_logger is
//! unavailable offline). `MEMASCEND_LOG=debug|info|warn|error`.

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger {
    max: Level,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.max
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            eprintln!(
                "[{:<5} {}] {}",
                record.level(),
                record.target().split("::").last().unwrap_or(""),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

/// Install the logger once; subsequent calls are no-ops.
pub fn init() {
    let level = match std::env::var("MEMASCEND_LOG").as_deref() {
        Ok("trace") => Level::Trace,
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    };
    let logger = Box::leak(Box::new(StderrLogger { max: level }));
    if log::set_logger(logger).is_ok() {
        log::set_max_level(LevelFilter::Trace);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }
}
