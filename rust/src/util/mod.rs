//! Foundation utilities.
//!
//! Everything here replaces a crate that is unavailable in this offline
//! environment (see DESIGN.md §3): `json` ≈ serde_json, `cli` ≈ clap,
//! `par` ≈ rayon, `rng` ≈ rand, `bench` ≈ criterion, `proptest` ≈
//! proptest, `human` ≈ humansize.

pub mod bench;
pub mod cli;
pub mod events;
pub mod human;
pub mod json;
pub mod logger;
pub mod par;
pub mod proptest;
pub mod rng;
pub mod stage;
