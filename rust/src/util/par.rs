//! Chunked data-parallel helpers over std scoped threads (rayon analog).
//!
//! The fused overflow check and the CPU Adam step are "OpenMP-parallel
//! tiled loops" in the paper; this module provides that shape.  Thread
//! count defaults to available parallelism (1 in this container — the
//! structure is still exercised and tested with forced thread counts).

use std::sync::atomic::{AtomicBool, Ordering};

pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `len` items into at most `threads` contiguous chunks of
/// near-equal size. Returns (start, end) pairs; never returns empty chunks.
pub fn chunks(len: usize, threads: usize) -> Vec<(usize, usize)> {
    if len == 0 {
        return vec![];
    }
    let t = threads.max(1).min(len);
    let base = len / t;
    let extra = len % t;
    let mut out = Vec::with_capacity(t);
    let mut start = 0;
    for i in 0..t {
        let sz = base + usize::from(i < extra);
        out.push((start, start + sz));
        start += sz;
    }
    out
}

/// Run `f(chunk_index, start..end slice)` over disjoint mutable chunks.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], threads: usize, chunk_hint: usize, f: F)
where
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let t = threads.max(1);
    if t == 1 {
        // fast path: no thread spawn cost on single-core machines
        for (i, (s, e)) in chunks(n, chunk_div(n, chunk_hint)).into_iter().enumerate() {
            f(i, s, &mut data[s..e]);
        }
        return;
    }
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut offset = 0;
        for (i, (s, e)) in chunks(n, t).into_iter().enumerate() {
            let (head, tail) = rest.split_at_mut(e - offset);
            rest = tail;
            offset = e;
            let fr = &f;
            scope.spawn(move || fr(i, s, head));
        }
    });
}

fn chunk_div(n: usize, chunk_hint: usize) -> usize {
    if chunk_hint == 0 {
        1
    } else {
        n.div_ceil(chunk_hint)
    }
}

/// Parallel any-reduction with cooperative early exit: each worker scans
/// its chunk and polls the shared flag between tiles (paper Algorithm 1's
/// "early exit from all threads").
pub fn par_any<T: Sync, F>(data: &[T], threads: usize, tile: usize, pred: F) -> bool
where
    F: Fn(&[T]) -> bool + Sync,
{
    let found = AtomicBool::new(false);
    let t = threads.max(1);
    if t == 1 || data.len() < tile * 2 {
        for tile_slice in data.chunks(tile.max(1)) {
            if pred(tile_slice) {
                return true;
            }
        }
        return false;
    }
    std::thread::scope(|scope| {
        for (s, e) in chunks(data.len(), t) {
            let slice = &data[s..e];
            let found = &found;
            let pred = &pred;
            scope.spawn(move || {
                for tile_slice in slice.chunks(tile.max(1)) {
                    if found.load(Ordering::Relaxed) {
                        return;
                    }
                    if pred(tile_slice) {
                        found.store(true, Ordering::Relaxed);
                        return;
                    }
                }
            });
        }
    });
    found.load(Ordering::Relaxed)
}

// NOTE: the old `par_map` (per-call scoped-thread fan-out) lived here;
// I/O fan-out now goes through the persistent queues in
// `crate::ssd::queue` instead, so only the compute-side helpers remain.

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_exactly() {
        for len in [0usize, 1, 7, 64, 1000] {
            for t in [1usize, 2, 3, 8, 64] {
                let cs = chunks(len, t);
                let mut pos = 0;
                for (s, e) in &cs {
                    assert_eq!(*s, pos);
                    assert!(e > s);
                    pos = *e;
                }
                assert_eq!(pos, len);
            }
        }
    }

    #[test]
    fn par_chunks_mut_touches_all() {
        for threads in [1, 4] {
            let mut v = vec![0u32; 1003];
            par_chunks_mut(&mut v, threads, 100, |_, start, slice| {
                for (i, x) in slice.iter_mut().enumerate() {
                    *x = (start + i) as u32;
                }
            });
            assert!(v.iter().enumerate().all(|(i, &x)| x == i as u32));
        }
    }

    #[test]
    fn par_any_finds_needle() {
        let mut v = vec![0.0f32; 10_000];
        v[9_999] = f32::INFINITY;
        for threads in [1, 4] {
            assert!(par_any(&v, threads, 512, |s| s.iter().any(|x| x.is_infinite())));
        }
        v[9_999] = 1.0;
        for threads in [1, 4] {
            assert!(!par_any(&v, threads, 512, |s| s.iter().any(|x| x.is_infinite())));
        }
    }

}
