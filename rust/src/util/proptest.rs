//! Randomized property-test driver (the proptest crate is unavailable
//! offline — this is the in-repo analog, documented in DESIGN.md §3).
//!
//! A property is a closure over a seeded RNG; the driver runs it for N
//! seeds and, on failure, retries the failing seed with progressively
//! smaller `size` hints (shrinking-lite) to report the smallest
//! reproduction it can find.  Deterministic: failures print the seed,
//! and `PROPTEST_SEED` reruns a single case.

use crate::util::rng::Xoshiro256;

pub struct Config {
    pub cases: usize,
    pub start_seed: u64,
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 64, start_seed: 0x5EED, max_size: 1 << 12 }
    }
}

/// Run `prop(rng, size)` for `cfg.cases` seeds. `prop` returns
/// `Err(description)` on property violation.
pub fn check<F>(name: &str, cfg: Config, prop: F)
where
    F: Fn(&mut Xoshiro256, usize) -> Result<(), String>,
{
    if let Ok(seed) = std::env::var("PROPTEST_SEED") {
        let seed: u64 = seed.parse().expect("PROPTEST_SEED must be u64");
        let mut rng = Xoshiro256::new(seed);
        if let Err(msg) = prop(&mut rng, cfg.max_size) {
            panic!("[{name}] failed at PROPTEST_SEED={seed}: {msg}");
        }
        return;
    }
    for case in 0..cfg.cases {
        let seed = cfg.start_seed.wrapping_add(case as u64);
        // size ramps up across cases so early failures are small
        let size = (cfg.max_size * (case + 1)).div_ceil(cfg.cases).max(1);
        let mut rng = Xoshiro256::new(seed);
        if let Err(msg) = prop(&mut rng, size) {
            // shrinking-lite: replay the same seed at smaller sizes,
            // report the smallest size that still fails.
            let mut smallest = (size, msg);
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = Xoshiro256::new(seed);
                match prop(&mut rng, s) {
                    Err(m) => {
                        smallest = (s, m);
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "[{name}] property failed (seed={seed}, size={}): {}\n\
                 rerun with PROPTEST_SEED={seed}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Convenience: assert-like helper inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", Config::default(), |rng, size| {
            let a = rng.below(size.max(1)) as u64;
            let b = rng.below(size.max(1)) as u64;
            prop_assert!(a + b == b + a, "never");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        check("always-small", Config { cases: 50, ..Default::default() }, |rng, size| {
            let v = rng.below(size.max(1));
            prop_assert!(v < 100, "v={v} exceeded bound");
            Ok(())
        });
    }
}
