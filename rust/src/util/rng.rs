//! Deterministic PRNGs (the `rand` crate is unavailable offline).
//!
//! SplitMix64 for seeding / cheap streams, Xoshiro256++ for bulk
//! generation (weight init, synthetic data, property tests).  Both are
//! the reference algorithms from Vigna et al.; determinism across runs
//! is what makes the baseline-vs-MemAscend loss-parity test exact.

#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Raw generator state — checkpoint/resume support.  Restoring via
    /// [`Xoshiro256::from_state`] continues the exact sequence.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill with N(0, std) f32 values — weight init.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for x in out.iter_mut() {
            *x = self.normal() as f32 * std;
        }
    }

    /// Zipf-like rank sampler over [0, n): P(k) ∝ 1/(k+1)^s.
    /// Used by the synthetic corpus to mimic natural token frequency.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // inverse-cdf on the fly is O(n); sample via rejection instead
        // (Devroye) — fine for vocab-scale n.
        loop {
            let u = self.next_f64();
            let v = self.next_f64();
            let x = ((n as f64 + 1.0).powf(1.0 - s) * u + 1.0 - u)
                .powf(1.0 / (1.0 - s));
            let k = x.floor() as usize;
            if k >= 1 && k <= n {
                let ratio = (1.0 + 1.0 / k as f64).powf(s - 1.0) * k as f64
                    / (k as f64 + 1.0);
                let t = (2.0f64).powf(s - 1.0);
                if v * k as f64 * (t - ratio) / (t - 1.0) <= 1.0 {
                    return k - 1;
                }
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Xoshiro256::new(1);
        for _ in 0..1000 {
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::new(7);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Xoshiro256::new(3);
        let mut counts = vec![0usize; 100];
        for _ in 0..10_000 {
            counts[r.zipf(100, 1.2)] += 1;
        }
        assert!(counts[0] > counts[50] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
