//! Compute-side stage executor: the conversion half of the staged-tile
//! pipelines.
//!
//! The NVMe queue workers ([`crate::ssd::IoExecutor`]) exist to keep
//! the devices saturated; running dtype conversion on them serializes
//! decode *behind* the next read on the same queue — the back-to-back
//! read+upconvert the PR-1 ROADMAP item called out.  This pool is the
//! other half of the split: a small set of persistent compute workers
//! that CPU-bound stage jobs (f16→f32 upconvert, f32→f16 downconvert,
//! bf16 repacks) run on, so decode of tile *k* overlaps the device read
//! of tile *k+1*:
//!
//! ```text
//!   NVMe queue:   [read k] [read k+1] [write k]  [read k+2] …
//!   stage pool:            [decode k] [decode k+1] …
//!   caller:                            [Adam k] …
//! ```
//!
//! Mechanically it *is* an [`IoExecutor`] (same FIFO, same per-job
//! panic containment, same drain-on-drop) under different thread names
//! — the type exists so the two pools can never be confused at a call
//! site: a `StageExecutor` argument always means "compute work, off
//! the I/O path".  Completion plumbing is the caller's business —
//! stage jobs typically close over a [`crate::ssd::IoHandle`]
//! completer and chain follow-up submissions (e.g. the tile
//! write-back) themselves.

use crate::ssd::IoExecutor;

/// Persistent compute-worker pool for conversion/packing stages.
pub struct StageExecutor {
    pool: IoExecutor,
}

impl StageExecutor {
    pub fn new(workers: usize) -> Self {
        Self { pool: IoExecutor::with_thread_prefix(workers, "ma-stage") }
    }

    pub fn worker_count(&self) -> usize {
        self.pool.worker_count()
    }

    /// Enqueue an owned job; returns immediately.  A panicking job is
    /// contained (queued jobs behind it still run; any completer it
    /// owned drops to "abandoned" instead of hanging its waiter).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.pool.submit(job)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn runs_all_jobs_before_drop() {
        let exec = StageExecutor::new(3);
        let n = Arc::new(AtomicUsize::new(0));
        for _ in 0..200 {
            let n = Arc::clone(&n);
            exec.submit(move || {
                n.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(exec); // drains the queue + joins workers
        assert_eq!(n.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn panicking_job_does_not_kill_the_worker() {
        let exec = StageExecutor::new(1); // one worker: a dead worker stalls the queue
        exec.submit(|| panic!("stage job panic"));
        let done = Arc::new(AtomicUsize::new(0));
        let d = Arc::clone(&done);
        exec.submit(move || {
            d.fetch_add(1, Ordering::Relaxed);
        });
        drop(exec);
        assert_eq!(done.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn jobs_can_chain_completions_across_pools() {
        // the staged-tile shape: an I/O-side completer resolved from a
        // stage job, like downconvert chaining into write-back
        let exec = StageExecutor::new(2);
        let (completer, handle) = crate::ssd::IoHandle::<u32>::pair();
        exec.submit(move || completer.complete(Ok(41 + 1)));
        assert_eq!(handle.wait().unwrap(), 42);
    }
}
