//! Integration tests over the full stack: AOT artifacts → PJRT runtime
//! → offload engine → trainer.  Require `make artifacts` (the smoke
//! config) to have run.

use std::path::{Path, PathBuf};

use memascend::config::{MemAscendFlags, Precision, TrainSpec};
use memascend::runtime::{Runtime, TensorBuf, ValueRef};
use memascend::train::{TrainOpts, Trainer};


/// Early-return when AOT artifacts are absent so the tier-1 gate
/// (`cargo test -q`) stays green on machines and CI runners without
/// jax; run `make artifacts` to enable the PJRT-backed tests.
macro_rules! require_artifacts {
    () => {
        if !Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/smoke/manifest.json")
            .exists()
        {
            eprintln!("skipping: run `make artifacts` to enable this test");
            return;
        }
    };
}

fn artifacts() -> PathBuf {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/smoke");
    assert!(
        p.join("manifest.json").exists(),
        "run `make artifacts` before `cargo test`"
    );
    p
}

fn storage(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ma-it-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn smoke_spec(flags: MemAscendFlags) -> TrainSpec {
    TrainSpec {
        batch: 2,
        seq: 16,
        flags,
        // modest initial scale so smoke runs don't spend steps skipping
        init_loss_scale: 1024.0,
        ..Default::default()
    }
}

fn run_smoke(flags: MemAscendFlags, steps: usize, tag: &str) -> memascend::metrics::RunReport {
    let dir = storage(tag);
    let opts = TrainOpts { steps, seed: 42, log_every: 0, loss_csv: None };
    let mut t = Trainer::new(&artifacts(), &dir, smoke_spec(flags), &opts).unwrap();
    let r = t.run(&opts).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    r
}

#[test]
fn training_decreases_loss() {
    require_artifacts!();
    let r = run_smoke(MemAscendFlags::memascend(), 15, "loss");
    let first = r.steps.first().unwrap().loss;
    let last = r.mean_tail_loss(3);
    assert!(
        last < first - 0.1,
        "loss did not decrease: {first} -> {last}"
    );
    // smoke vocab=64 -> initial loss near ln(64)=4.16
    assert!((3.5..4.8).contains(&first), "initial loss {first}");
}

#[test]
fn loss_parity_baseline_vs_memascend() {
    require_artifacts!();
    // The paper's Fig. 19 claim: MemAscend is numerically inert.
    // Ours is stronger: bit-identical loss trajectories.
    let zi = run_smoke(MemAscendFlags::baseline(), 8, "par-zi");
    let ma = run_smoke(MemAscendFlags::memascend(), 8, "par-ma");
    assert_eq!(zi.steps.len(), ma.steps.len());
    for (a, b) in zi.steps.iter().zip(&ma.steps) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {}", a.step);
        assert_eq!(a.overflowed, b.overflowed);
        assert_eq!(a.loss_scale, b.loss_scale);
    }
}

#[test]
fn ablation_matrix_all_combos_train() {
    require_artifacts!();
    for (i, flags) in MemAscendFlags::all_combinations().into_iter().enumerate() {
        let r = run_smoke(flags, 2, &format!("ab{i}"));
        assert_eq!(r.steps.len(), 2, "combo {i} failed");
        assert!(r.steps[1].loss.is_finite());
    }
}

#[test]
fn bf16_mixed_precision_trains_without_scaler() {
    require_artifacts!();
    let dir = storage("bf16");
    let mut spec = smoke_spec(MemAscendFlags::memascend());
    spec.precision = Precision::MixedBF16;
    spec.init_loss_scale = 1.0;
    let opts = TrainOpts { steps: 10, seed: 42, log_every: 0, loss_csv: None };
    let mut t = Trainer::new(&artifacts(), &dir, spec, &opts).unwrap();
    let r = t.run(&opts).unwrap();
    assert!(r.steps.iter().all(|s| !s.overflowed));
    assert!(r.steps.iter().all(|s| s.loss_scale == 1.0));
    assert!(r.mean_tail_loss(3) < r.steps[0].loss);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bf16_optimizer_states_reduce_io_volume() {
    require_artifacts!();
    let dir1 = storage("iof32");
    let dir2 = storage("iobf16");
    let opts = TrainOpts { steps: 4, seed: 42, log_every: 0, loss_csv: None };
    let spec32 = smoke_spec(MemAscendFlags::memascend());
    let mut spec16 = smoke_spec(MemAscendFlags::memascend());
    spec16.optim_dtype = memascend::dtype::DType::BF16;
    let mut t32 = Trainer::new(&artifacts(), &dir1, spec32, &opts).unwrap();
    let mut t16 = Trainer::new(&artifacts(), &dir2, spec16, &opts).unwrap();
    let r32 = t32.run(&opts).unwrap();
    let r16 = t16.run(&opts).unwrap();
    // Fig. 20: the bf16 optimizer cuts per-step I/O volume
    assert!(
        (r16.io_bytes_per_step as f64) < 0.75 * r32.io_bytes_per_step as f64,
        "bf16 {} vs f32 {}",
        r16.io_bytes_per_step,
        r32.io_bytes_per_step
    );
    // and still learns
    assert!(r16.mean_tail_loss(2) < r16.steps[0].loss + 0.05);
    std::fs::remove_dir_all(&dir1).ok();
    std::fs::remove_dir_all(&dir2).ok();
}

#[test]
fn simulated_data_parallel_ranks_train() {
    require_artifacts!();
    let dir = storage("ranks");
    let mut spec = smoke_spec(MemAscendFlags::memascend());
    spec.ranks = 2;
    let opts = TrainOpts { steps: 6, seed: 42, log_every: 0, loss_csv: None };
    let mut t = Trainer::new(&artifacts(), &dir, spec, &opts).unwrap();
    let r = t.run(&opts).unwrap();
    assert_eq!(r.steps[0].tokens, 2 * 2 * 16);
    assert!(r.mean_tail_loss(2) < r.steps[0].loss);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hlo_overflow_kernel_matches_native() {
    require_artifacts!();
    // The L1 Pallas overflow kernel (AOT artifact) and the L3 native
    // fused check must agree — three implementations, one verdict.
    let rt = Runtime::load(&artifacts()).unwrap();
    let chunk = rt.manifest().config.chunk;
    let mut clean = vec![0.5f32; chunk];
    let flag = rt
        .run("overflow_check", &[ValueRef::F32(&clean)])
        .unwrap()[0]
        .as_i32()
        .unwrap()[0];
    assert_eq!(flag, 0);
    assert!(!memascend::overflow::fused_overflow_check(&clean, 1));

    for special in [f32::INFINITY, f32::NEG_INFINITY, f32::NAN] {
        clean[chunk / 2] = special;
        let flag = rt
            .run("overflow_check", &[ValueRef::F32(&clean)])
            .unwrap()[0]
            .as_i32()
            .unwrap()[0];
        assert_eq!(flag, 1, "HLO missed {special}");
        assert!(memascend::overflow::fused_overflow_check(&clean, 1));
        clean[chunk / 2] = 0.5;
    }
}

#[test]
fn hlo_adam_kernel_matches_native() {
    require_artifacts!();
    let rt = Runtime::load(&artifacts()).unwrap();
    let chunk = rt.manifest().config.chunk;
    let am = rt.manifest().adam.clone();
    let hp = memascend::optimizer::AdamParams {
        lr: am.lr,
        beta1: am.beta1,
        beta2: am.beta2,
        eps: am.eps,
        weight_decay: am.weight_decay,
    };
    let mut rng = memascend::util::rng::Xoshiro256::new(11);
    let p: Vec<f32> = (0..chunk).map(|_| rng.normal() as f32).collect();
    let g: Vec<f32> = (0..chunk).map(|_| rng.normal() as f32).collect();
    let m = vec![0.1f32; chunk];
    let v = vec![0.2f32; chunk];
    let t = 3u64;
    let bc = vec![
        1.0 - (am.beta1 as f32).powi(t as i32),
        1.0 - (am.beta2 as f32).powi(t as i32),
    ];
    let out = rt
        .run(
            "adam_step",
            &[
                ValueRef::F32(&bc),
                ValueRef::F32(&p),
                ValueRef::F32(&g),
                ValueRef::F32(&m),
                ValueRef::F32(&v),
            ],
        )
        .unwrap();
    let p_hlo = out[0].as_f32().unwrap();
    let (mut p_n, mut m_n, mut v_n) = (p, m, v);
    memascend::optimizer::adam_step_f32(&mut p_n, &g, &mut m_n, &mut v_n, t, 1.0, &hp, 1);
    for i in 0..chunk {
        assert!(
            (p_hlo[i] - p_n[i]).abs() < 1e-5,
            "elem {i}: hlo {} native {}",
            p_hlo[i],
            p_n[i]
        );
    }
}

#[test]
fn runtime_rejects_bad_args() {
    require_artifacts!();
    let rt = Runtime::load(&artifacts()).unwrap();
    // wrong arity
    assert!(rt.run("embed_fwd", &[]).is_err());
    // wrong shape
    let short = vec![0i32; 3];
    let table = vec![0.0f32; 64 * 32];
    let r = rt.run("embed_fwd", &[ValueRef::I32(&short), ValueRef::F32(&table)]);
    assert!(r.is_err());
    // wrong dtype
    let toks_f32 = vec![0.0f32; 32];
    let r = rt.run("embed_fwd", &[ValueRef::F32(&toks_f32), ValueRef::F32(&table)]);
    assert!(r.is_err());
    // unknown stage
    assert!(rt.run("nope", &[]).is_err());
}

#[test]
fn lease_backed_args_run_bit_identical_to_owned() {
    require_artifacts!();
    // The tentpole's end-to-end claim through the *real* PJRT path:
    // uploading from pinned lease memory produces the same bits as
    // uploading from an owned Vec.
    use memascend::pinned::{
        AlignedAllocator, ArenaConfig, Cat, MemoryTracker, Mode, PinnedArena,
    };
    use std::sync::Arc;
    let rt = Runtime::load(&artifacts()).unwrap();
    let chunk = rt.manifest().config.chunk;
    let arena = PinnedArena::new(
        Arc::new(AlignedAllocator::new(Mode::Real, Arc::new(MemoryTracker::new()))),
        ArenaConfig::default(),
    );
    let mut rng = memascend::util::rng::Xoshiro256::new(23);
    let vals: Vec<f32> = (0..chunk).map(|_| rng.normal() as f32).collect();
    let mut lease = arena.lease(chunk * 4, Cat::SwapBuf).unwrap();
    lease.as_f32_mut().copy_from_slice(&vals);
    let view = TensorBuf::from_lease(lease).unwrap();
    let owned = rt.run("overflow_check", &[ValueRef::F32(&vals)]).unwrap();
    let leased = rt.run("overflow_check", &[view.as_value()]).unwrap();
    assert_eq!(owned[0].as_i32().unwrap(), leased[0].as_i32().unwrap());
    // and a run_into destination receives the adam result in place
    let am = rt.manifest().adam.clone();
    let t = 2u64;
    let bc = vec![
        1.0 - (am.beta1 as f32).powi(t as i32),
        1.0 - (am.beta2 as f32).powi(t as i32),
    ];
    let g: Vec<f32> = (0..chunk).map(|_| rng.normal() as f32).collect();
    let m = vec![0.1f32; chunk];
    let v = vec![0.2f32; chunk];
    let args = [
        ValueRef::F32(&bc),
        view.as_value(),
        ValueRef::F32(&g),
        ValueRef::F32(&m),
        ValueRef::F32(&v),
    ];
    let owned_out = rt.run("adam_step", &args).unwrap();
    let n_results = rt.manifest().stage("adam_step").unwrap().results.len();
    let mut dst = arena.lease(chunk * 4, Cat::SwapBuf).unwrap();
    {
        let mut dests: Vec<Option<&mut [f32]>> = (0..n_results).map(|_| None).collect();
        dests[0] = Some(dst.as_f32_mut());
        let redirected = rt.run_into("adam_step", &args, &mut dests).unwrap();
        assert!(redirected[0].as_f32().unwrap().is_empty(), "placeholder expected");
    }
    let want = owned_out[0].as_f32().unwrap();
    let got = dst.as_f32();
    assert_eq!(want.len(), got.len());
    for i in 0..want.len() {
        assert_eq!(want[i].to_bits(), got[i].to_bits(), "elem {i} diverged");
    }
}

#[test]
fn fs_engine_mode_trains_identically() {
    require_artifacts!();
    // direct_nvme off: the filesystem baseline must produce the same
    // numbers (storage backend is numerically inert).
    let mut flags = MemAscendFlags::memascend();
    flags.direct_nvme = false;
    let a = run_smoke(flags, 5, "fsmode");
    let b = run_smoke(MemAscendFlags::memascend(), 5, "dmode");
    for (x, y) in a.steps.iter().zip(&b.steps) {
        assert_eq!(x.loss.to_bits(), y.loss.to_bits());
    }
}

#[test]
fn ssd_activation_spill_is_numerically_inert() {
    require_artifacts!();
    // SSDTrain integration: spilling checkpoints to SSD must not change
    // a single bit of the trajectory (it is the same fp16 roundtrip).
    let dir_a = storage("spill-host");
    let dir_b = storage("spill-ssd");
    let opts = TrainOpts { steps: 5, seed: 42, log_every: 0, loss_csv: None };
    let host = smoke_spec(MemAscendFlags::memascend());
    let mut spilled = smoke_spec(MemAscendFlags::memascend());
    spilled.act_host_budget = 0; // every checkpoint goes to the SSD
    let mut ta = Trainer::new(&artifacts(), &dir_a, host, &opts).unwrap();
    let mut tb = Trainer::new(&artifacts(), &dir_b, spilled, &opts).unwrap();
    let ra = ta.run(&opts).unwrap();
    let rb = tb.run(&opts).unwrap();
    for (a, b) in ra.steps.iter().zip(&rb.steps) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {}", a.step);
    }
    // and the spilled run moved strictly more SSD bytes
    assert!(rb.io_bytes_per_step > ra.io_bytes_per_step);
    std::fs::remove_dir_all(&dir_a).ok();
    std::fs::remove_dir_all(&dir_b).ok();
}

#[test]
fn checkpoint_resume_continues_bit_identically() {
    require_artifacts!();
    use memascend::ssd::{FaultyEngine, NvmeEngine, OpMask, RetryEngine, RetryPolicy};
    use std::sync::Arc;
    let mut spec = smoke_spec(MemAscendFlags::memascend());
    spec.ckpt_interval_steps = 2;

    // uninterrupted reference: 6 steps straight through
    let dir_ref = storage("ck-ref");
    let opts6 = TrainOpts { steps: 6, seed: 42, log_every: 0, loss_csv: None };
    let mut t_ref = Trainer::new(&artifacts(), &dir_ref, spec.clone(), &opts6).unwrap();
    let full = t_ref.run(&opts6).unwrap();

    // interrupted run: 4 steps (epochs 1 and 2), with transient flush
    // faults injected under the retry layer — the checkpoint barriers
    // must absorb them without changing a byte
    let dir = storage("ck-resume");
    let opts4 = TrainOpts { steps: 4, seed: 42, log_every: 0, loss_csv: None };
    let mut t1 = Trainer::new(&artifacts(), &dir, spec.clone(), &opts4).unwrap();
    let faulty = Arc::new(FaultyEngine::transient(
        t1.engine.nvme.clone(),
        1,
        OpMask::FLUSH,
    ));
    t1.engine.nvme = Arc::new(RetryEngine::new(faulty.clone(), RetryPolicy::attempts(3)));
    let first = t1.run(&opts4).unwrap();
    assert!(
        faulty.injected.load(std::sync::atomic::Ordering::Relaxed) > 0,
        "flush faults were never injected"
    );
    assert!(t1.engine.nvme.stats().retries > 0, "retries not metered");
    assert_eq!(t1.journal_epoch(), 2);
    drop(t1); // kill right after the epoch-2 commit

    // restart from the journal and run the remaining 2 steps
    let opts2 = TrainOpts { steps: 2, seed: 42, log_every: 0, loss_csv: None };
    let mut t2 = Trainer::resume(&artifacts(), &dir, spec, &opts2).unwrap();
    assert_eq!(t2.steps_done(), 4);
    assert_eq!(t2.journal_epoch(), 2);
    let rest = t2.run(&opts2).unwrap();

    // bit-identical trajectory across the kill/restart boundary
    assert_eq!(full.steps.len(), first.steps.len() + rest.steps.len());
    for (a, b) in full.steps.iter().zip(first.steps.iter().chain(&rest.steps)) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {}", a.step);
        assert_eq!(a.loss_scale, b.loss_scale, "step {}", a.step);
        assert_eq!(a.overflowed, b.overflowed, "step {}", a.step);
    }
    // and bit-identical on-SSD state at the end
    for key in ["layers.0.wq/fp16", "layers.0.wq/master", "embed/adam_m"] {
        let n = t_ref.engine.nvme.len_of(key).unwrap();
        let mut a = vec![0u8; n];
        let mut b = vec![0u8; n];
        t_ref.engine.nvme.read(key, &mut a).unwrap();
        t2.engine.nvme.read(key, &mut b).unwrap();
        assert_eq!(a, b, "stored key {key} diverged after resume");
    }
    drop(t_ref);
    drop(t2);
    std::fs::remove_dir_all(&dir_ref).ok();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn coalesced_prefetch_profile_resumes_and_degrades_gracefully() {
    require_artifacts!();
    use memascend::ssd::NvmeEngine;
    let mut spec = smoke_spec(MemAscendFlags::memascend());
    spec.ckpt_interval_steps = 2;
    spec.optim_coalesce_bytes = 1 << 20;
    spec.fetch_coalesce = true;
    spec.prefetch_profile = true;

    // plain-path reference: no coalescing, no profile, no journal
    let full = run_smoke(MemAscendFlags::memascend(), 6, "pf-ref");

    // 4 steps with the full fetch stack, checkpointing every 2
    let dir = storage("pf-resume");
    let opts4 = TrainOpts { steps: 4, seed: 42, log_every: 0, loss_csv: None };
    let mut t1 = Trainer::new(&artifacts(), &dir, spec.clone(), &opts4).unwrap();
    let first = t1.run(&opts4).unwrap();
    // coalesced ranged reads: >=2x fewer fetch submissions than the
    // per-tensor path, and the recorded digests always hit (no
    // fallback) on a stable plan
    assert!(
        first.steps[0].fetch_submissions * 2 <= full.steps[0].fetch_submissions,
        "coalesced fetch submitted {} reads vs {} per-tensor",
        first.steps[0].fetch_submissions,
        full.steps[0].fetch_submissions,
    );
    // step 1 records (its bwd pass legitimately flags one fallback:
    // the store already holds the fwd profile but not yet the bwd
    // digest); every later step must replay without fallbacks
    assert!(first.steps[1..].iter().all(|s| s.prefetch_fallbacks == 0));
    // the step profile persisted with the epoch commit
    let profile_len = t1
        .engine
        .nvme
        .len_of("swap/profile")
        .expect("profile blob missing after checkpoint");
    // tamper with the persisted blob (same length, so the write is
    // accepted): the journaled digest must catch it on resume
    t1.engine.nvme.write("swap/profile", &vec![0xAB; profile_len]).unwrap();
    drop(t1);

    // resume degrades to re-record mode (a performance hint, never an
    // error) and the trajectory still matches the plain path bit for bit
    let opts2 = TrainOpts { steps: 2, seed: 42, log_every: 0, loss_csv: None };
    let mut t2 = Trainer::resume(&artifacts(), &dir, spec, &opts2).unwrap();
    let rest = t2.run(&opts2).unwrap();
    assert_eq!(full.steps.len(), first.steps.len() + rest.steps.len());
    for (a, b) in full.steps.iter().zip(first.steps.iter().chain(&rest.steps)) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {}", a.step);
    }
    assert!(rest.steps.iter().all(|s| s.fetch_submissions > 0));
    drop(t2);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_recovers_crashes_and_refuses_mismatched_config() {
    require_artifacts!();
    use memascend::ssd::NvmeEngine;
    let mut spec = smoke_spec(MemAscendFlags::memascend());
    spec.ckpt_interval_steps = 2;

    // uninterrupted reference for both recovery legs below
    let dir_ref = storage("ck-rec-ref");
    let opts4 = TrainOpts { steps: 4, seed: 42, log_every: 0, loss_csv: None };
    let mut t_ref = Trainer::new(&artifacts(), &dir_ref, spec.clone(), &opts4).unwrap();
    let full = t_ref.run(&opts4).unwrap();
    drop(t_ref);

    // crash mid-epoch: epoch 1 commits after step 2, step 3's
    // write-backs land in the shadow extents, then the process dies.
    // Resume recovers epoch 1 — its extents were never overwritten —
    // and rerunning steps 3-4 is bit-identical to the reference
    let dir = storage("ck-dirty");
    let opts3 = TrainOpts { steps: 3, seed: 42, log_every: 0, loss_csv: None };
    let mut t = Trainer::new(&artifacts(), &dir, spec.clone(), &opts3).unwrap();
    t.run(&opts3).unwrap();
    drop(t);
    let opts2 = TrainOpts { steps: 2, seed: 42, log_every: 0, loss_csv: None };
    let mut t = Trainer::resume(&artifacts(), &dir, spec.clone(), &opts2).unwrap();
    assert_eq!(t.steps_done(), 2, "mid-epoch crash rewinds to epoch 1");
    assert_eq!(t.journal_epoch(), 1);
    let rest = t.run(&opts2).unwrap();
    for (a, b) in full.steps[2..].iter().zip(&rest.steps) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {}", a.step);
    }
    drop(t);
    std::fs::remove_dir_all(&dir).ok();

    // 4 steps (epochs 1, 2), then tear the newest journal slot: the
    // dual-slot load drops epoch 2 and the walk-back lands on epoch 1,
    // whose extents the steps-3-4 window never touched — resume
    // *recovers* (the old dirty-marker refusal is gone) and the rerun
    // is bit-identical
    let dir = storage("ck-torn");
    let mut t = Trainer::new(&artifacts(), &dir, spec.clone(), &opts4).unwrap();
    t.run(&opts4).unwrap();
    let nvme = t.engine.nvme.clone();
    drop(t);
    let slot = memascend::ckpt::journal::SLOT_A; // epoch 2 is even -> slot A
    let len = nvme.len_of(slot).unwrap();
    nvme.write(slot, &vec![0x5Au8; len]).unwrap();
    nvme.flush(slot).unwrap();
    drop(nvme);
    let mut t = Trainer::resume(&artifacts(), &dir, spec.clone(), &opts2).unwrap();
    assert_eq!(t.steps_done(), 2, "torn epoch 2 walks back to epoch 1");
    assert_eq!(t.journal_epoch(), 1);
    let rest = t.run(&opts2).unwrap();
    for (a, b) in full.steps[2..].iter().zip(&rest.steps) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {}", a.step);
    }
    drop(t);
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir_ref).ok();

    // a clean 2-step run resumes — but only with the original seed
    let dir = storage("ck-seed");
    let opts2 = TrainOpts { steps: 2, seed: 42, log_every: 0, loss_csv: None };
    let mut t = Trainer::new(&artifacts(), &dir, spec.clone(), &opts2).unwrap();
    t.run(&opts2).unwrap();
    drop(t);
    let bad_seed = TrainOpts { steps: 1, seed: 43, log_every: 0, loss_csv: None };
    let err = Trainer::resume(&artifacts(), &dir, spec.clone(), &bad_seed).unwrap_err();
    assert!(err.to_string().contains("seeded with"), "{err}");
    // and with no journal at all, the error says how to get one
    let dir_none = storage("ck-none");
    let opts0 = TrainOpts { steps: 1, seed: 42, log_every: 0, loss_csv: None };
    let mut spec_none = spec.clone();
    spec_none.ckpt_interval_steps = 0;
    let mut t = Trainer::new(&artifacts(), &dir_none, spec_none.clone(), &opts0).unwrap();
    t.run(&opts0).unwrap();
    drop(t);
    let err = Trainer::resume(&artifacts(), &dir_none, spec_none, &opts0).unwrap_err();
    assert!(err.to_string().contains("no checkpoint journal"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir_none).ok();
}

#[test]
fn partial_act_budget_splits_tiers_and_stays_inert() {
    require_artifacts!();
    let dir = storage("spill-split");
    let opts = TrainOpts { steps: 3, seed: 42, log_every: 0, loss_csv: None };
    let mut spec = smoke_spec(MemAscendFlags::memascend());
    // one checkpoint slot in host memory, the other on SSD
    spec.act_host_budget = 2 * 16 * 32 * 2; // b*s*h*2 bytes = 1 slot
    let mut t = Trainer::new(&artifacts(), &dir, spec, &opts).unwrap();
    let r = t.run(&opts).unwrap();
    let full = run_smoke(MemAscendFlags::memascend(), 3, "spill-ref");
    for (a, b) in r.steps.iter().zip(&full.steps) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    }
    std::fs::remove_dir_all(&dir).ok();
}
