//! Crash-recovery and fault-injection soak tests over the real engine
//! stack — no AOT artifacts needed, so these run in the tier-1 CI
//! scope (`cargo test -q`).
//!
//! Contracts, end to end through `DirectEngine` + the async queue +
//! the staged-tile optimizer + the shadow-paging layer:
//!
//! - **chaos soak**: transient NVMe faults under the bounded-backoff
//!   retry layer are invisible to training state — a faulty run
//!   finishes bit-identical to a fault-free run, with every absorbed
//!   retry metered in `IoSnapshot::retries`.  The seeded variant reads
//!   `MEMASCEND_CHAOS_SEED` so CI can soak a matrix of fault patterns,
//!   and `MEMASCEND_CHAOS_MODE` selects the injection shape: `bit-flip`
//!   (read-side corruption the integrity layer must detect and the
//!   retry layer heal; durable write-side rot must abort typed) or
//!   `latency-spike` (seeded stalls that must never change a byte);
//! - **clean abort**: persistent faults exhaust the retry budget and
//!   surface the typed `RetryExhausted` error (no deadlock, no hang),
//!   and a commit that failed mid-flush leaves the previously
//!   committed epoch fully intact;
//! - **kill-and-restart at every phase**: a crash between epochs, mid
//!   optimizer window, after the journal slot write but before the
//!   in-memory flip, or mid commit flush always recovers the newest
//!   *valid* epoch, and the continuation is bit-identical to an
//!   uninterrupted run — shadow paging routes post-commit write-backs
//!   to the other physical extent, so committed bytes are never
//!   overwritten.

use std::sync::Arc;

use memascend::ckpt::{CkptState, Journal, ShadowEngine};
use memascend::optimizer::states::state_keys;
use memascend::optimizer::{
    flush_groups, step_groups_tiled, AdamParams, OptimState, StateDtype,
};
use memascend::pinned::{
    AlignedAllocator, ArenaConfig, MemoryTracker, Mode, PinnedArena,
};
use memascend::ssd::{
    AsyncEngine, DirectEngine, FaultyEngine, IntegrityEngine, NvmeEngine, OpKind,
    OpMask, RetryEngine, RetryPolicy,
};
use memascend::util::rng::Xoshiro256;
use memascend::util::stage::StageExecutor;

/// Small tiles so even these modest groups run a multi-tile pipeline.
const TILE_BYTES: usize = 4096;
const DEPTH: usize = 2;

fn arena() -> Arc<PinnedArena> {
    let alloc = AlignedAllocator::new(Mode::Real, Arc::new(MemoryTracker::new()));
    PinnedArena::new(Arc::new(alloc), ArenaConfig::default())
}

fn tmp(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("ma-rec-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn direct(dir: &std::path::Path) -> Arc<DirectEngine> {
    Arc::new(DirectEngine::new(dir, 2, 1 << 22, 1).unwrap())
}

/// Deterministic per-step gradients, shared by every run in a test so
/// interrupted and uninterrupted trajectories see the same data.
fn grads_for(step: u64, sizes: &[usize]) -> Vec<Vec<f32>> {
    let mut rng = Xoshiro256::new(0x5EED ^ step);
    sizes
        .iter()
        .map(|&n| (0..n).map(|_| rng.normal() as f32).collect())
        .collect()
}

/// Initialize identical optimizer groups (`g0`, `g1`, ...) on `engine`.
fn init_states(engine: &dyn NvmeEngine, sizes: &[usize]) -> Vec<OptimState> {
    let mut rng = Xoshiro256::new(99);
    sizes
        .iter()
        .enumerate()
        .map(|(g, &n)| {
            let vals: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            OptimState::init(engine, &format!("g{g}"), &vals, StateDtype::F32).unwrap()
        })
        .collect()
}

fn fp16_keys(states: &[OptimState]) -> Vec<String> {
    states.iter().map(|s| format!("{}/fp16", s.group)).collect()
}

/// Every logical key a checkpoint epoch of `states` covers.
fn all_keys(states: &[OptimState]) -> Vec<String> {
    let mut keys = Vec::new();
    for st in states {
        keys.extend(state_keys(&st.group));
        keys.push(format!("{}/fp16", st.group));
    }
    keys
}

/// Rebuild the optimizer handles from metadata alone (no gather, no
/// re-init) — what a resumed trainer does.
fn reopen_states(sizes: &[usize]) -> Vec<OptimState> {
    sizes
        .iter()
        .enumerate()
        .map(|(g, &n)| OptimState {
            group: format!("g{g}"),
            numel: n,
            dtype: StateDtype::F32,
        })
        .collect()
}

/// Run the staged-tile optimizer for the given 1-based step range.
fn run_steps(
    engine: Arc<dyn NvmeEngine>,
    states: &[OptimState],
    sizes: &[usize],
    steps: std::ops::RangeInclusive<u64>,
) -> anyhow::Result<()> {
    let aio = AsyncEngine::new(engine, 2);
    let stage = StageExecutor::new(2);
    let arena = arena();
    let hp = AdamParams { weight_decay: 0.01, ..Default::default() };
    let keys = fp16_keys(states);
    for t in steps {
        let grads = grads_for(t, sizes);
        let gr: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        step_groups_tiled(
            &aio, &stage, &arena, states, &gr, &keys, t, 1.0, &hp, 1, TILE_BYTES,
            DEPTH,
        )?;
    }
    Ok(())
}

/// Trainer-shaped window steps over a shadow-paged stack: each applied
/// step folds the extent map forward (`advance`) so the next step
/// reads back what this one wrote.
fn run_steps_shadow(
    shadow: &Arc<ShadowEngine>,
    states: &[OptimState],
    sizes: &[usize],
    steps: std::ops::RangeInclusive<u64>,
) -> anyhow::Result<()> {
    for t in steps {
        let eng: Arc<dyn NvmeEngine> = shadow.clone();
        run_steps(eng, states, sizes, t..=t)?;
        shadow.advance();
    }
    Ok(())
}

/// All four stored streams (master/m/v/fp16) of one group.
fn group_bytes(engine: &dyn NvmeEngine, group: &str, numel: usize) -> Vec<Vec<u8>> {
    let mut out = Vec::new();
    for (key, width) in [
        (format!("{group}/master"), 4usize),
        (format!("{group}/adam_m"), 4),
        (format!("{group}/adam_v"), 4),
        (format!("{group}/fp16"), 2),
    ] {
        let mut buf = vec![0u8; numel * width];
        engine.read(&key, &mut buf).unwrap();
        out.push(buf);
    }
    out
}

/// Journal record with the given key triples and the cursors every
/// test here shares.
fn base_ckpt(epoch: u64, steps_done: u64, keys: Vec<(String, usize, u8)>) -> CkptState {
    CkptState {
        epoch,
        steps_done,
        applied_steps: steps_done,
        seed: 99,
        model: "recovery-test".into(),
        dtype: "f32".into(),
        corpus_rng: [1, 2, 3, 4],
        scale: 1.0,
        good_steps: 0,
        overflows: 0,
        growths: 0,
        tile_bytes: TILE_BYTES,
        tile_depth: DEPTH,
        prefetch_depth: 1,
        sched_lead_us: 2_000,
        act_host_budget: usize::MAX,
        keys,
        layout_digest: None,
        profile_digest: None,
    }
}

/// Minimal journal record naming every key of `states` on a raw
/// (un-shadowed) engine — everything lives at extent 0.
fn ckpt_state(
    epoch: u64,
    steps_done: u64,
    engine: &dyn NvmeEngine,
    states: &[OptimState],
) -> CkptState {
    let keys = all_keys(states)
        .into_iter()
        .map(|k| {
            let len = engine.len_of(&k).unwrap();
            (k, len, 0u8)
        })
        .collect();
    base_ckpt(epoch, steps_done, keys)
}

/// The trainer's commit sequence over a shadow-paged stack: flush each
/// stream's newest extent, write the slot record carrying the extent
/// map, then flip the in-memory routing.  `flip_after: false` models a
/// crash between the (durable) slot write and the (in-memory) flip.
fn commit_epoch(
    journal: &Journal,
    shadow: &Arc<ShadowEngine>,
    states: &[OptimState],
    epoch: u64,
    steps_done: u64,
    flip_after: bool,
) -> anyhow::Result<()> {
    flush_groups(shadow.as_ref(), states, &fp16_keys(states))?;
    let keys = all_keys(states)
        .into_iter()
        .map(|k| {
            let ext = shadow.newest_ext(&k);
            let len = shadow.len_of(&k).unwrap();
            (k, len, ext)
        })
        .collect();
    journal.commit(&base_ckpt(epoch, steps_done, keys))?;
    if flip_after {
        shadow.flip();
    }
    Ok(())
}

#[test]
fn chaos_transient_faults_finish_bit_identical() {
    let sizes = [3000usize, 1500];
    let dir_a = tmp("chaos-clean");
    let dir_b = tmp("chaos-faulty");
    let eng_a: Arc<dyn NvmeEngine> = direct(&dir_a);
    // every distinct op on the faulty stack fails its first 2 attempts;
    // a 4-attempt retry budget must absorb all of it
    let faulty = Arc::new(FaultyEngine::transient(direct(&dir_b), 2, OpMask::ALL));
    let eng_b: Arc<dyn NvmeEngine> =
        Arc::new(RetryEngine::new(faulty.clone(), RetryPolicy::attempts(4)));

    // initialization runs through the retry layer too
    let st_a = init_states(eng_a.as_ref(), &sizes);
    let st_b = init_states(eng_b.as_ref(), &sizes);
    run_steps(eng_a.clone(), &st_a, &sizes, 1..=3).unwrap();
    run_steps(eng_b.clone(), &st_b, &sizes, 1..=3).unwrap();
    flush_groups(eng_a.as_ref(), &st_a, &fp16_keys(&st_a)).unwrap();
    flush_groups(eng_b.as_ref(), &st_b, &fp16_keys(&st_b)).unwrap();

    // faults were really injected, really absorbed, and metered
    let injected = faulty.injected.load(std::sync::atomic::Ordering::Relaxed);
    assert!(injected > 0, "chaos run injected no faults");
    assert!(
        eng_b.stats().retries >= injected,
        "retries {} < injected {injected}",
        eng_b.stats().retries
    );

    // and not one byte of training state diverged
    for (g, &n) in sizes.iter().enumerate() {
        let a = group_bytes(eng_a.as_ref(), &format!("g{g}"), n);
        let b = group_bytes(eng_b.as_ref(), &format!("g{g}"), n);
        assert_eq!(a, b, "group g{g} diverged under transient faults");
    }
    std::fs::remove_dir_all(&dir_a).ok();
    std::fs::remove_dir_all(&dir_b).ok();
}

/// Seeded probabilistic chaos soak over the full shadow-paged stack,
/// including two commit/flip cycles.  `MEMASCEND_CHAOS_SEED` selects
/// the fault pattern (CI runs a matrix of seeds); any seed must finish
/// bit-identical to the fault-free run.
#[test]
fn chaos_soak_seeded_random_faults_finish_bit_identical() {
    let seed: u64 = std::env::var("MEMASCEND_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let sizes = [2200usize, 900];

    let dir_a = tmp(&format!("soak-clean-{seed}"));
    let eng_a: Arc<dyn NvmeEngine> = direct(&dir_a);
    let st_a = init_states(eng_a.as_ref(), &sizes);
    run_steps(eng_a.clone(), &st_a, &sizes, 1..=3).unwrap();
    flush_groups(eng_a.as_ref(), &st_a, &fp16_keys(&st_a)).unwrap();

    // ~9% of every op kind fails, deterministically by seed; an
    // 8-attempt budget makes exhaustion astronomically unlikely
    let dir_b = tmp(&format!("soak-faulty-{seed}"));
    let faulty = Arc::new(
        FaultyEngine::new(direct(&dir_b), 96, seed).with_mask(OpMask::ALL),
    );
    let retry: Arc<dyn NvmeEngine> =
        Arc::new(RetryEngine::new(faulty.clone(), RetryPolicy::attempts(8)));
    let shadow = Arc::new(ShadowEngine::new(retry.clone()));
    let st_b = init_states(shadow.as_ref(), &sizes);
    shadow.register(all_keys(&st_b));
    let journal = Journal::new(shadow.clone());
    run_steps_shadow(&shadow, &st_b, &sizes, 1..=1).unwrap();
    commit_epoch(&journal, &shadow, &st_b, 1, 1, true).unwrap();
    run_steps_shadow(&shadow, &st_b, &sizes, 2..=3).unwrap();
    commit_epoch(&journal, &shadow, &st_b, 2, 3, true).unwrap();

    let injected = faulty.injected.load(std::sync::atomic::Ordering::Relaxed);
    assert!(injected > 0, "seed {seed} injected no faults");
    assert!(
        retry.stats().retries >= injected,
        "retries {} < injected {injected}",
        retry.stats().retries
    );
    assert_eq!(retry.stats().retry_exhaustions, 0);

    for (g, &n) in sizes.iter().enumerate() {
        let a = group_bytes(eng_a.as_ref(), &format!("g{g}"), n);
        let b = group_bytes(shadow.as_ref(), &format!("g{g}"), n);
        assert_eq!(a, b, "seed {seed}: group g{g} diverged under chaos");
    }
    std::fs::remove_dir_all(&dir_a).ok();
    std::fs::remove_dir_all(&dir_b).ok();
}

/// Seeded corruption/straggler chaos soak.  `MEMASCEND_CHAOS_MODE`
/// selects the injection shape (CI runs a seed × mode matrix):
///
/// - `bit-flip` (default): read-side flips under
///   `Retry(Integrity(Faulty))` are detected by the checksum layer and
///   healed by a re-read — the run finishes bit-identical with every
///   detection metered; a durable write-side flip exhausts the retry
///   budget and aborts with the typed mismatch, never serving corrupt
///   bytes;
/// - `latency-spike`: seeded stalls slow ops down but never change a
///   byte.
#[test]
fn chaos_soak_corruption_and_straggler_modes() {
    let seed: u64 = std::env::var("MEMASCEND_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let mode =
        std::env::var("MEMASCEND_CHAOS_MODE").unwrap_or_else(|_| "bit-flip".into());
    let sizes = [1800usize, 700];

    // fault-free reference trajectory
    let dir_a = tmp(&format!("cmode-clean-{mode}-{seed}"));
    let eng_a: Arc<dyn NvmeEngine> = direct(&dir_a);
    let st_a = init_states(eng_a.as_ref(), &sizes);
    run_steps(eng_a.clone(), &st_a, &sizes, 1..=3).unwrap();
    flush_groups(eng_a.as_ref(), &st_a, &fp16_keys(&st_a)).unwrap();

    let dir_b = tmp(&format!("cmode-faulty-{mode}-{seed}"));
    let eng_b: Arc<dyn NvmeEngine> = match mode.as_str() {
        "bit-flip" => {
            // ~20% of whole-key reads corrupt one bit in the out buffer
            // (writes and ranged reads stay clean): every flip is a
            // transient misread — of stream bytes or of the sidecar
            // sums the verify path fetches — that the integrity layer
            // must catch and the retry layer must heal.  Ranged reads
            // are spared because the sum-maintenance path re-reads
            // partially-covered edge blocks through this engine; a flip
            // there would *durably* rot the sidecar, which is the
            // write-side contract tested separately below.
            let faulty = Arc::new(
                FaultyEngine::new(direct(&dir_b), 0, seed)
                    .with_bit_flips(200, seed)
                    .with_flip_mask(OpMask::NONE.with(OpKind::Read)),
            );
            // a generous budget: at a 20% flip rate a whole-key read
            // (data + sums fetch) fails ~1 attempt in 3
            let integrity = Arc::new(IntegrityEngine::new(faulty.clone()));
            let eng: Arc<dyn NvmeEngine> =
                Arc::new(RetryEngine::new(integrity, RetryPolicy::attempts(12)));
            let st_b = init_states(eng.as_ref(), &sizes);
            run_steps(eng.clone(), &st_b, &sizes, 1..=3).unwrap();
            flush_groups(eng.as_ref(), &st_b, &fp16_keys(&st_b)).unwrap();
            let corrupted =
                faulty.corrupted.load(std::sync::atomic::Ordering::Relaxed);
            assert!(corrupted > 0, "seed {seed} flipped no bits");
            let snap = eng.stats();
            assert!(
                snap.integrity_failures >= corrupted,
                "seed {seed}: {} of {corrupted} flips detected — a flip \
                 slipped past the checksum layer",
                snap.integrity_failures
            );
            assert!(snap.retries >= snap.integrity_failures);
            assert_eq!(snap.retry_exhaustions, 0, "transient flips must heal");
            eng
        }
        "latency-spike" => {
            // ~6% of data ops stall 2ms (+ seeded jitter): stragglers
            // slow the pipeline but must never change a byte
            let faulty = Arc::new(FaultyEngine::new(direct(&dir_b), 0, seed).with_latency(
                64,
                std::time::Duration::from_millis(2),
                std::time::Duration::from_millis(1),
                seed,
            ));
            let eng: Arc<dyn NvmeEngine> = faulty.clone();
            let st_b = init_states(eng.as_ref(), &sizes);
            run_steps(eng.clone(), &st_b, &sizes, 1..=3).unwrap();
            flush_groups(eng.as_ref(), &st_b, &fp16_keys(&st_b)).unwrap();
            assert!(
                faulty.delayed.load(std::sync::atomic::Ordering::Relaxed) > 0,
                "seed {seed} served no latency spikes"
            );
            eng
        }
        other => panic!("unknown MEMASCEND_CHAOS_MODE '{other}'"),
    };

    // not one byte of training state diverged under either shape
    // (bit-flip reads here go back through the verified stack, so
    // lingering read flips are healed, not compared)
    for (g, &n) in sizes.iter().enumerate() {
        let a = group_bytes(eng_a.as_ref(), &format!("g{g}"), n);
        let b = group_bytes(eng_b.as_ref(), &format!("g{g}"), n);
        assert_eq!(a, b, "seed {seed} mode {mode}: group g{g} diverged");
    }

    // durable rot half of the bit-flip contract: a write-side flip rots
    // the stored bytes; the verified read must refuse them typed after
    // exhausting the retry budget — training never sees corrupt data
    if mode == "bit-flip" {
        let dir_c = tmp(&format!("cmode-rot-{seed}"));
        let rotter = Arc::new(
            FaultyEngine::new(direct(&dir_c), 0, seed)
                .with_bit_flips(1024, seed)
                .with_flip_mask(OpMask::NONE.with(OpKind::Write)),
        );
        let verified: Arc<dyn NvmeEngine> = Arc::new(RetryEngine::new(
            Arc::new(IntegrityEngine::new(rotter.clone())),
            RetryPolicy::attempts(3),
        ));
        verified.write("rotten", &[0x5Au8; 4096]).unwrap();
        let mut out = vec![0u8; 4096];
        let err = verified.read("rotten", &mut out).unwrap_err();
        assert!(
            err.to_string().contains("integrity mismatch"),
            "durable rot must surface the typed mismatch, got: {err}"
        );
        assert!(
            err.to_string().contains("retry exhausted"),
            "durable rot must exhaust the retry budget, got: {err}"
        );
        assert!(verified.stats().retry_exhaustions > 0);
        std::fs::remove_dir_all(&dir_c).ok();
    }
    std::fs::remove_dir_all(&dir_a).ok();
    std::fs::remove_dir_all(&dir_b).ok();
}

#[test]
fn persistent_faults_abort_cleanly_without_partial_commit() {
    let sizes = [2000usize];
    let dir = tmp("persist");
    let inner = direct(&dir);
    let eng: Arc<dyn NvmeEngine> = inner.clone();
    let states = init_states(eng.as_ref(), &sizes);
    run_steps(eng.clone(), &states, &sizes, 1..=1).unwrap();
    flush_groups(eng.as_ref(), &states, &fp16_keys(&states)).unwrap();
    let journal = Journal::new(eng.clone());
    journal.commit(&ckpt_state(1, 1, eng.as_ref(), &states)).unwrap();

    // a persistent data fault exhausts the bounded retry budget and
    // surfaces the typed error — the step returns (this test completing
    // at all is the no-deadlock assertion)
    let faulty: Arc<dyn NvmeEngine> = Arc::new(RetryEngine::new(
        Arc::new(FaultyEngine::transient(inner.clone(), u32::MAX, OpMask::DATA)),
        RetryPolicy::attempts(2),
    ));
    let err = run_steps(faulty.clone(), &states, &sizes, 2..=2).unwrap_err();
    assert!(err.to_string().contains("injected"), "unexpected error: {err}");
    assert!(
        err.to_string().contains("retry exhausted"),
        "exhaustion must surface the typed error, got: {err}"
    );
    assert!(
        faulty.stats().retry_exhaustions > 0,
        "exhaustions must be metered separately"
    );

    // a journal commit through the dead stack fails without touching
    // the committed epoch — no partial commit
    let bad = Journal::new(faulty);
    assert!(bad.commit(&ckpt_state(2, 2, eng.as_ref(), &states)).is_err());
    let back = Journal::new(eng).load().expect("epoch 1 must survive");
    assert_eq!(back.epoch, 1);
    back.validate_keys(inner.as_ref()).unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn kill_and_restart_from_reopened_storage_is_bit_identical() {
    let sizes = [2500usize, 700];

    // uninterrupted reference: 4 steps straight through
    let dir_ref = tmp("kr-ref");
    let eng_ref: Arc<dyn NvmeEngine> = direct(&dir_ref);
    let st_ref = init_states(eng_ref.as_ref(), &sizes);
    run_steps(eng_ref.clone(), &st_ref, &sizes, 1..=4).unwrap();
    flush_groups(eng_ref.as_ref(), &st_ref, &fp16_keys(&st_ref)).unwrap();

    // interrupted run: 2 steps, flush barriers, journal commit, then
    // drop every handle — the moral equivalent of kill -9 right after
    // the commit
    let dir = tmp("kr-live");
    {
        let eng: Arc<dyn NvmeEngine> = direct(&dir);
        let states = init_states(eng.as_ref(), &sizes);
        run_steps(eng.clone(), &states, &sizes, 1..=2).unwrap();
        flush_groups(eng.as_ref(), &states, &fp16_keys(&states)).unwrap();
        let journal = Journal::new(eng.clone());
        journal.commit(&ckpt_state(1, 2, eng.as_ref(), &states)).unwrap();
    }

    // restart: reopen the storage root cold, replay the journal,
    // rebuild the optimizer handles from metadata alone (no gather, no
    // re-init), and continue
    let eng2: Arc<dyn NvmeEngine> = direct(&dir);
    let journal = Journal::new(eng2.clone());
    let ck = journal.load().expect("journal must survive the restart");
    assert_eq!(ck.epoch, 1);
    assert_eq!(ck.steps_done, 2);
    ck.validate_keys(eng2.as_ref()).unwrap();
    let resumed = reopen_states(&sizes);
    run_steps(eng2.clone(), &resumed, &sizes, 3..=4).unwrap();
    flush_groups(eng2.as_ref(), &resumed, &fp16_keys(&resumed)).unwrap();

    for (g, &n) in sizes.iter().enumerate() {
        let a = group_bytes(eng_ref.as_ref(), &format!("g{g}"), n);
        let b = group_bytes(eng2.as_ref(), &format!("g{g}"), n);
        assert_eq!(a, b, "group g{g}: kill-and-restart diverged");
    }
    std::fs::remove_dir_all(&dir_ref).ok();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn torn_commit_recovers_previous_epoch_on_restart() {
    let sizes = [1200usize];
    let dir = tmp("torn");
    {
        let eng: Arc<dyn NvmeEngine> = direct(&dir);
        let states = init_states(eng.as_ref(), &sizes);
        run_steps(eng.clone(), &states, &sizes, 1..=1).unwrap();
        flush_groups(eng.as_ref(), &states, &fp16_keys(&states)).unwrap();
        let journal = Journal::new(eng.clone());
        journal.commit(&ckpt_state(1, 1, eng.as_ref(), &states)).unwrap();
        journal.commit(&ckpt_state(2, 2, eng.as_ref(), &states)).unwrap();
        // tear epoch 2's slot: same-length garbage, as a crash mid
        // journal write would leave (epoch 2 is even -> slot A)
        let slot = memascend::ckpt::journal::SLOT_A;
        let len = eng.len_of(slot).unwrap();
        eng.write(slot, &vec![0xA5u8; len]).unwrap();
    }
    // restart: the torn slot fails its checksum and the dual-slot load
    // falls back to epoch 1 — whose keys still validate
    let eng2: Arc<dyn NvmeEngine> = direct(&dir);
    let ck = Journal::new(eng2.clone()).load().expect("previous epoch survives");
    assert_eq!(ck.epoch, 1, "torn commit must roll back to epoch 1");
    ck.validate_keys(eng2.as_ref()).unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// THE tentpole property: a crash *between* epochs — after epoch 2's
/// commit the newest slot rots — walks resume back to epoch 1, whose
/// extents the post-commit window never overwrote, and rerunning from
/// there is bit-identical to an uninterrupted run.
#[test]
fn between_epoch_crash_walks_back_and_continues_bit_identical() {
    let sizes = [2500usize, 700];

    // uninterrupted reference: 4 steps straight through
    let dir_ref = tmp("wb-ref");
    let eng_ref: Arc<dyn NvmeEngine> = direct(&dir_ref);
    let st_ref = init_states(eng_ref.as_ref(), &sizes);
    run_steps(eng_ref.clone(), &st_ref, &sizes, 1..=4).unwrap();
    flush_groups(eng_ref.as_ref(), &st_ref, &fp16_keys(&st_ref)).unwrap();

    let dir = tmp("wb-live");
    {
        let shadow = Arc::new(ShadowEngine::new(direct(&dir)));
        let states = init_states(shadow.as_ref(), &sizes);
        shadow.register(all_keys(&states));
        let journal = Journal::new(shadow.clone());
        run_steps_shadow(&shadow, &states, &sizes, 1..=2).unwrap();
        commit_epoch(&journal, &shadow, &states, 1, 2, true).unwrap();
        run_steps_shadow(&shadow, &states, &sizes, 3..=4).unwrap();
        commit_epoch(&journal, &shadow, &states, 2, 4, true).unwrap();
        // bit-rot epoch 2's slot after the commit (even epoch -> slot
        // A): the newest record no longer checksums
        let slot = memascend::ckpt::journal::SLOT_A;
        let len = shadow.len_of(slot).unwrap();
        let mut buf = vec![0u8; len];
        shadow.read(slot, &mut buf).unwrap();
        buf[40] ^= 0xFF;
        shadow.write(slot, &buf).unwrap();
    }

    // restart: epoch 2 drops out of the candidate walk; epoch 1's
    // extent map installs and its bytes — extent 0, untouched by the
    // post-commit window that wrote extent 1 — validate
    let shadow2 = Arc::new(ShadowEngine::new(direct(&dir)));
    let candidates = Journal::new(shadow2.clone()).load_all();
    assert_eq!(candidates.len(), 1, "torn newest epoch must drop out");
    let ck = candidates.into_iter().next().unwrap();
    assert_eq!(ck.epoch, 1, "walk-back must land on epoch 1");
    ck.validate_keys(shadow2.inner().as_ref()).unwrap();
    shadow2.install(ck.extent_map());

    let resumed = reopen_states(&sizes);
    run_steps_shadow(&shadow2, &resumed, &sizes, 3..=4).unwrap();
    flush_groups(shadow2.as_ref(), &resumed, &fp16_keys(&resumed)).unwrap();
    for (g, &n) in sizes.iter().enumerate() {
        let a = group_bytes(eng_ref.as_ref(), &format!("g{g}"), n);
        let b = group_bytes(shadow2.as_ref(), &format!("g{g}"), n);
        assert_eq!(a, b, "group g{g}: between-epoch crash recovery diverged");
    }
    std::fs::remove_dir_all(&dir_ref).ok();
    std::fs::remove_dir_all(&dir).ok();
}

/// Crash after the journal slot write but before the in-memory flip:
/// the slot record is the durable authority, so reopening resumes the
/// just-committed epoch bit-identically — the flip loses nothing.
#[test]
fn crash_after_slot_write_before_flip_resumes_newest_epoch() {
    let sizes = [1800usize];

    let dir_ref = tmp("flip-ref");
    let eng_ref: Arc<dyn NvmeEngine> = direct(&dir_ref);
    let st_ref = init_states(eng_ref.as_ref(), &sizes);
    run_steps(eng_ref.clone(), &st_ref, &sizes, 1..=4).unwrap();
    flush_groups(eng_ref.as_ref(), &st_ref, &fp16_keys(&st_ref)).unwrap();

    let dir = tmp("flip-live");
    {
        let shadow = Arc::new(ShadowEngine::new(direct(&dir)));
        let states = init_states(shadow.as_ref(), &sizes);
        shadow.register(all_keys(&states));
        let journal = Journal::new(shadow.clone());
        run_steps_shadow(&shadow, &states, &sizes, 1..=2).unwrap();
        commit_epoch(&journal, &shadow, &states, 1, 2, true).unwrap();
        run_steps_shadow(&shadow, &states, &sizes, 3..=4).unwrap();
        // slot written, flip never happens — kill -9 in the gap
        commit_epoch(&journal, &shadow, &states, 2, 4, false).unwrap();
    }

    let shadow2 = Arc::new(ShadowEngine::new(direct(&dir)));
    let ck = Journal::new(shadow2.clone()).load().expect("epoch 2 is durable");
    assert_eq!(ck.epoch, 2);
    ck.validate_keys(shadow2.inner().as_ref()).unwrap();
    shadow2.install(ck.extent_map());
    for (g, &n) in sizes.iter().enumerate() {
        let a = group_bytes(eng_ref.as_ref(), &format!("g{g}"), n);
        let b = group_bytes(shadow2.as_ref(), &format!("g{g}"), n);
        assert_eq!(a, b, "group g{g}: pre-flip crash recovery diverged");
    }
    std::fs::remove_dir_all(&dir_ref).ok();
    std::fs::remove_dir_all(&dir).ok();
}

/// Crash mid optimizer window: steps ran past the last commit but no
/// new epoch was journaled.  The committed epoch's extents were never
/// written (the window targeted the shadow extents), so recovery
/// rewinds to it and the rerun is bit-identical.
#[test]
fn mid_window_crash_recovers_last_committed_epoch() {
    let sizes = [1200usize, 600];

    let dir_ref = tmp("mw-ref");
    let eng_ref: Arc<dyn NvmeEngine> = direct(&dir_ref);
    let st_ref = init_states(eng_ref.as_ref(), &sizes);
    run_steps(eng_ref.clone(), &st_ref, &sizes, 1..=4).unwrap();
    flush_groups(eng_ref.as_ref(), &st_ref, &fp16_keys(&st_ref)).unwrap();

    let dir = tmp("mw-live");
    {
        let shadow = Arc::new(ShadowEngine::new(direct(&dir)));
        let states = init_states(shadow.as_ref(), &sizes);
        shadow.register(all_keys(&states));
        let journal = Journal::new(shadow.clone());
        run_steps_shadow(&shadow, &states, &sizes, 1..=2).unwrap();
        commit_epoch(&journal, &shadow, &states, 1, 2, true).unwrap();
        // one step into the next window, then die — no flush, no commit
        run_steps_shadow(&shadow, &states, &sizes, 3..=3).unwrap();
    }

    let shadow2 = Arc::new(ShadowEngine::new(direct(&dir)));
    let ck = Journal::new(shadow2.clone()).load().expect("epoch 1 survives");
    assert_eq!(ck.epoch, 1);
    ck.validate_keys(shadow2.inner().as_ref()).unwrap();
    shadow2.install(ck.extent_map());
    let resumed = reopen_states(&sizes);
    run_steps_shadow(&shadow2, &resumed, &sizes, 3..=4).unwrap();
    flush_groups(shadow2.as_ref(), &resumed, &fp16_keys(&resumed)).unwrap();
    for (g, &n) in sizes.iter().enumerate() {
        let a = group_bytes(eng_ref.as_ref(), &format!("g{g}"), n);
        let b = group_bytes(shadow2.as_ref(), &format!("g{g}"), n);
        assert_eq!(a, b, "group g{g}: mid-window crash recovery diverged");
    }
    std::fs::remove_dir_all(&dir_ref).ok();
    std::fs::remove_dir_all(&dir).ok();
}

/// Commit aborted mid-flush by a persistent fault: the flush barrier
/// fails before the slot write, the typed exhaustion error surfaces,
/// and the previously committed epoch stays fully recoverable.
#[test]
fn mid_commit_flush_fault_aborts_and_previous_epoch_survives() {
    let sizes = [1000usize];
    let dir = tmp("mcf");
    let inner = direct(&dir);
    let shadow = Arc::new(ShadowEngine::new(inner.clone()));
    let states = init_states(shadow.as_ref(), &sizes);
    shadow.register(all_keys(&states));
    let journal = Journal::new(shadow.clone());
    run_steps_shadow(&shadow, &states, &sizes, 1..=2).unwrap();
    commit_epoch(&journal, &shadow, &states, 1, 2, true).unwrap();
    run_steps_shadow(&shadow, &states, &sizes, 3..=4).unwrap();

    // a commit stack whose flush barrier is persistently dead, routed
    // to the same extents the live shadow map points at
    let dead: Arc<dyn NvmeEngine> = Arc::new(RetryEngine::new(
        Arc::new(FaultyEngine::transient(inner.clone(), u32::MAX, OpMask::FLUSH)),
        RetryPolicy::attempts(2),
    ));
    let shadow_bad = Arc::new(ShadowEngine::new(dead.clone()));
    shadow_bad.install(
        all_keys(&states)
            .into_iter()
            .map(|k| {
                let ext = shadow.newest_ext(&k);
                (k, ext)
            })
            .collect::<Vec<_>>(),
    );
    let journal_bad = Journal::new(shadow_bad.clone());
    let err =
        commit_epoch(&journal_bad, &shadow_bad, &states, 2, 4, true).unwrap_err();
    assert!(
        err.to_string().contains("retry exhausted"),
        "mid-commit flush fault must surface exhaustion, got: {err}"
    );
    assert!(dead.stats().retry_exhaustions > 0);

    // epoch 1 is untouched and fully recoverable
    let ck = Journal::new(shadow.clone()).load().expect("epoch 1 survives");
    assert_eq!(ck.epoch, 1);
    ck.validate_keys(inner.as_ref()).unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
