//! Multi-job tenancy chaos soak: co-tenant trainers on one shared
//! substrate (device + submission queue + arena) with per-job fault
//! injection, at the optimizer level so the suite runs everywhere (no
//! AOT artifacts needed).
//!
//! `MEMASCEND_TENANCY_SEED` reseeds the probabilistic fault pattern —
//! CI sweeps several seeds; every pattern must be absorbed (transient)
//! or contained (persistent) without touching the co-tenant, whose
//! stored streams must stay bit-identical to a solo run.

use std::sync::{Arc, Mutex};

use memascend::jobs::{JobRegistry, JobState, ScopedEngine};
use memascend::metrics::StepMetrics;
use memascend::optimizer::{step_groups_tiled, AdamParams, OptimState, StateDtype};
use memascend::pinned::{
    AlignedAllocator, ArenaConfig, MemoryTracker, Mode, PinnedArena, MAX_NAMESPACES,
};
use memascend::ssd::{
    AsyncEngine, FaultyEngine, FsEngine, IoExecutor, JobId, NvmeEngine, OpMask,
    RetryEngine, RetryPolicy,
};
use memascend::util::events::{EventKind, EventSink, MemorySink};
use memascend::util::rng::Xoshiro256;
use memascend::util::stage::StageExecutor;

const SIZES: [usize; 2] = [60_000, 30_000];
const TILE_BYTES: usize = 64 << 10;
const STEPS: u64 = 4;

fn chaos_seed() -> u64 {
    std::env::var("MEMASCEND_TENANCY_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

fn tmp(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "ma-tenancy-{tag}-{}-{}",
        chaos_seed(),
        std::process::id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn arena() -> Arc<PinnedArena> {
    PinnedArena::new(
        Arc::new(AlignedAllocator::new(Mode::Real, Arc::new(MemoryTracker::new()))),
        ArenaConfig::default(),
    )
}

fn fs_engine(dir: &std::path::Path) -> Arc<dyn NvmeEngine> {
    Arc::new(FsEngine::new(dir, 1, 512 << 10).unwrap())
}

fn grads_for(job: u16, step: u64) -> Vec<Vec<f32>> {
    let mut rng = Xoshiro256::new(((job as u64) << 32) ^ step ^ 0x7E4A);
    SIZES
        .iter()
        .map(|&n| (0..n).map(|_| rng.normal() as f32).collect())
        .collect()
}

fn init_states(engine: &dyn NvmeEngine, job: u16) -> Vec<OptimState> {
    let mut rng = Xoshiro256::new(500 + job as u64);
    SIZES
        .iter()
        .enumerate()
        .map(|(g, &n)| {
            let vals: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            OptimState::init(engine, &format!("g{g}"), &vals, StateDtype::F32).unwrap()
        })
        .collect()
}

fn fp16_keys(states: &[OptimState]) -> Vec<String> {
    states.iter().map(|s| format!("{}/fp16", s.group)).collect()
}

fn one_step(
    aio: &AsyncEngine,
    stage: &StageExecutor,
    arena: &Arc<PinnedArena>,
    states: &[OptimState],
    t: u64,
    job: u16,
) -> anyhow::Result<()> {
    let hp = AdamParams { weight_decay: 0.01, ..Default::default() };
    let grads = grads_for(job, t);
    let gr: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    step_groups_tiled(
        aio,
        stage,
        arena,
        states,
        &gr,
        &fp16_keys(states),
        t,
        1.0,
        &hp,
        1,
        TILE_BYTES,
        2,
    )?;
    Ok(())
}

fn all_bytes(engine: &dyn NvmeEngine) -> Vec<Vec<u8>> {
    let mut out = Vec::new();
    for (g, &n) in SIZES.iter().enumerate() {
        for (key, width) in [
            (format!("g{g}/master"), 4usize),
            (format!("g{g}/adam_m"), 4),
            (format!("g{g}/adam_v"), 4),
            (format!("g{g}/fp16"), 2),
        ] {
            let mut buf = vec![0u8; n * width];
            engine.read(&key, &mut buf).unwrap();
            out.push(buf);
        }
    }
    out
}

/// Solo reference: the job alone on its own clean stack.
fn run_solo(tag: &str, job: u16) -> Vec<Vec<u8>> {
    let dir = tmp(&format!("solo-{tag}{job}"));
    let eng = fs_engine(&dir);
    let states = init_states(eng.as_ref(), job);
    let aio = AsyncEngine::new(eng.clone(), 2);
    let stage = StageExecutor::new(2);
    let arena = arena();
    for t in 1..=STEPS {
        one_step(&aio, &stage, &arena, &states, t, job).unwrap();
    }
    let bytes = all_bytes(eng.as_ref());
    std::fs::remove_dir_all(&dir).ok();
    bytes
}

/// Spawn one clean tenant running the full step sequence through its
/// scoped view of the shared substrate.
fn spawn_clean_tenant(
    reg: &JobRegistry,
    base: &Arc<dyn NvmeEngine>,
    ioq: &Arc<IoExecutor>,
    shared_arena: &Arc<PinnedArena>,
    stage: &Arc<StageExecutor>,
    job: u16,
) {
    let id = JobId(job);
    let scoped: Arc<dyn NvmeEngine> = Arc::new(ScopedEngine::new(base.clone(), id));
    let states = init_states(scoped.as_ref(), job);
    let aio = AsyncEngine::with_executor(scoped, ioq.clone()).for_job(id);
    let ns = shared_arena.namespace(id.lane() as u32);
    let stage = stage.clone();
    reg.spawn(&format!("tenant{job}"), id, STEPS, move |t| {
        one_step(&aio, &stage, &ns, &states, t + 1, job)?;
        Ok(StepMetrics { step: t + 1, ..Default::default() })
    });
}

#[test]
fn probabilistic_faults_on_one_tenant_are_absorbed_and_contained() {
    // tenant 1 clean, tenant 2 under seeded probabilistic NVMe faults
    // absorbed by the bounded retry layer: BOTH must finish and BOTH
    // must be bit-identical to their solo runs
    let solo1 = run_solo("chaos", 1);
    let solo2 = run_solo("chaos", 2);
    let dir = tmp("chaos");
    let base = fs_engine(&dir);
    let ioq = Arc::new(IoExecutor::new(2));
    let shared_arena = arena();
    let stage = Arc::new(StageExecutor::new(2));
    let sink = MemorySink::new();
    let reg = JobRegistry::new(sink.clone() as Arc<dyn EventSink>);
    spawn_clean_tenant(&reg, &base, &ioq, &shared_arena, &stage, 1);
    let retry_probe = {
        let id = JobId(2);
        let scoped: Arc<dyn NvmeEngine> = Arc::new(ScopedEngine::new(base.clone(), id));
        // states are written through the CLEAN scoped view, faults are
        // injected under the step loop only — mirrors a device that
        // starts hiccuping mid-run
        let states = init_states(scoped.as_ref(), 2);
        let faulty: Arc<dyn NvmeEngine> =
            Arc::new(FaultyEngine::new(scoped, 48, chaos_seed()));
        let retry = Arc::new(RetryEngine::new(faulty, RetryPolicy::attempts(6)));
        let nvme: Arc<dyn NvmeEngine> = retry.clone();
        let aio = AsyncEngine::with_executor(nvme, ioq.clone()).for_job(id);
        let ns = shared_arena.namespace(id.lane() as u32);
        let stage = stage.clone();
        reg.spawn("chaos-tenant", id, STEPS, move |t| {
            one_step(&aio, &stage, &ns, &states, t + 1, 2)?;
            Ok(StepMetrics { step: t + 1, ..Default::default() })
        });
        retry
    };
    reg.join_all();

    assert_eq!(reg.state(JobId(1)), Some(JobState::Finished));
    assert_eq!(reg.state(JobId(2)), Some(JobState::Finished), "chaos not absorbed");
    assert!(
        !sink.events().iter().any(|e| e.kind == EventKind::JobFailed),
        "no job may fail under absorbed transient faults"
    );
    let scoped1 = ScopedEngine::new(base.clone(), JobId(1));
    let scoped2 = ScopedEngine::new(base.clone(), JobId(2));
    assert_eq!(all_bytes(&scoped1), solo1, "clean tenant diverged");
    assert_eq!(all_bytes(&scoped2), solo2, "chaos tenant diverged after retries");
    assert!(
        retry_probe.retries() > 0,
        "fault pattern injected nothing — the soak exercised no chaos"
    );
    let ns_sum: usize = (0..MAX_NAMESPACES)
        .map(|ns| shared_arena.ns_stats(ns).charged)
        .sum();
    assert_eq!(ns_sum, shared_arena.stats().reserved_bytes);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn persistent_fault_aborts_only_its_own_job() {
    let solo1 = run_solo("persist", 1);
    let dir = tmp("persist");
    let base = fs_engine(&dir);
    let ioq = Arc::new(IoExecutor::new(2));
    let shared_arena = arena();
    let stage = Arc::new(StageExecutor::new(2));
    let sink = MemorySink::new();
    let reg = JobRegistry::new(sink.clone() as Arc<dyn EventSink>);
    spawn_clean_tenant(&reg, &base, &ioq, &shared_arena, &stage, 1);
    {
        let id = JobId(2);
        let scoped: Arc<dyn NvmeEngine> = Arc::new(ScopedEngine::new(base.clone(), id));
        let faulty: Arc<dyn NvmeEngine> =
            Arc::new(FaultyEngine::transient(scoped, u32::MAX, OpMask::DATA));
        let retried: Arc<dyn NvmeEngine> =
            Arc::new(RetryEngine::new(faulty, RetryPolicy::attempts(3)));
        let first_error = Arc::new(Mutex::new(String::new()));
        let probe = first_error.clone();
        reg.spawn("broken-tenant", id, STEPS, move |_| {
            let mut rng = Xoshiro256::new(9);
            let vals: Vec<f32> = (0..2048).map(|_| rng.normal() as f32).collect();
            let res = OptimState::init(retried.as_ref(), "g0", &vals, StateDtype::F32);
            if let Err(e) = &res {
                *probe.lock().unwrap() = format!("{e:#}");
            }
            res.map(|_| StepMetrics::default())
        });
        reg.join_all();
        assert_eq!(reg.state(JobId(1)), Some(JobState::Finished), "co-tenant dragged down");
        assert_eq!(reg.state(JobId(2)), Some(JobState::Failed));
        assert!(
            !first_error.lock().unwrap().is_empty(),
            "persistent fault produced no error"
        );
    }
    let failures: Vec<_> = sink
        .events()
        .into_iter()
        .filter(|e| e.kind == EventKind::JobFailed)
        .collect();
    assert_eq!(failures.len(), 1, "exactly one job may fail");
    assert_eq!(failures[0].job, JobId(2), "failure attributed to the wrong job");
    let scoped1 = ScopedEngine::new(base.clone(), JobId(1));
    assert_eq!(
        all_bytes(&scoped1),
        solo1,
        "co-tenant bytes diverged under a neighbor's persistent fault"
    );
    std::fs::remove_dir_all(&dir).ok();
}
