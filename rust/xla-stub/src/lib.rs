//! Stub of the `xla-rs` API surface `memascend::runtime` compiles
//! against.
//!
//! The real backend needs an XLA C library (`XLA_EXTENSION_DIR`) that
//! CI machines and most dev boxes don't have.  This stub keeps every
//! signature the runtime uses so `cargo build` / `cargo test` work
//! everywhere; constructing a client fails at *runtime* with a clear
//! message, which is exactly where artifact-requiring integration
//! tests already bail.  Substitute a real `xla-rs` checkout via the
//! `xla` path dependency in `../Cargo.toml` to execute staged HLO.
//!
//! The surface mirrors the runtime's zero-copy boundary contract:
//! [`PjRtClient::buffer_from_host_buffer`] *borrows* its host slice
//! for the duration of the call only — the runtime may (and does)
//! point it straight into pinned-arena lease memory, so an
//! implementation must never retain the borrow or require an owned
//! buffer.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "xla backend unavailable: built against the in-tree xla-stub \
         (point the `xla` dependency at a real xla-rs checkout and set \
         XLA_EXTENSION_DIR to run PJRT stages)"
            .to_string(),
    ))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable()
    }

    /// Upload a host tensor.  `_data` is borrowed for this call only —
    /// callers upload straight out of pinned lease memory, so the
    /// slice must be consumed (copied/DMA'd) before returning.
    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

pub struct Literal;

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}
